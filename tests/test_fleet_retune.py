"""Fleet-scale online retuning: shard recording, epochal profiles,
store-ref hot swap, and runtime dispatch plans.

Covers the ISSUE-7 tentpole end to end at unit scale: bounded per-server
``ShardRecorder``s, weight-preserving ``Trace.merge_shards``, MANIFEST
epochs with the staleness rule, and the zero-re-jit hot swap (a jitted
step's impl choice provably changes at RUNTIME through the plan vector
while the jit cache stays at one entry).
"""
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, collectives as C, tuner
from repro.core.cell import OpCell
from repro.core.profiles import (MANIFEST_NAME, Profile, ProfileStore,
                                 Range, StoreRef, read_manifest,
                                 resolve_stores, write_manifest)
from repro.core.trace import (ShardRecorder, Trace, TraceEntry,
                              load_shard_latencies, shard_digest,
                              shard_meta)
from repro.core.tuner import FeedbackBackend, estimate_trace_cost


# ---------------------------------------------------------------------------
# ShardRecorder: bounded sampling across recompilations
# ---------------------------------------------------------------------------


def _rec(op="allreduce", p=4, nbytes=512, impl="default", phase="fwd"):
    return api.DispatchRecord(OpCell(op, p, nbytes), impl, phase)


def test_shard_recorder_aggregates_and_accepts_both_record_shapes():
    r = ShardRecorder("srv0")
    r.append(_rec())
    r.append(_rec())
    r.append(("allreduce", 4, 512, "default", "fwd"))   # legacy 5-tuple
    r.append(_rec(phase="bwd"))
    assert len(r) == 2
    assert r.total() == 4
    assert r.trace().cells() == {OpCell("allreduce", 4, 512): 4}


def test_shard_recorder_bounds_distinct_cells_and_accounts_drops():
    r = ShardRecorder("srv0", max_cells=4, seed=7)
    for i in range(50):
        r.append(_rec(nbytes=8 * (i + 1)))
    assert len(r) <= 4
    # every dispatch is either held in a cell count or accounted dropped
    assert r.total() + r.dropped == 50
    # held counts stay exact: re-dispatching a held cell never drops
    held = next(iter(r.trace().cells()))
    before = r.total()
    r.append(_rec(nbytes=held.nbytes))
    assert r.total() == before + 1


def test_shard_recorder_flush_writes_header_and_resets(tmp_path):
    r = ShardRecorder("srv3")
    for _ in range(5):
        r.append(_rec())
    r.observe(OpCell("allreduce", 4, 512), "allreduce_as_doubling", 1e-4)
    path = r.flush(tmp_path, epoch=2)
    assert path.name == "shard-srv3-e000002.jsonl"
    meta = shard_meta(path)
    assert meta["server"] == "srv3" and meta["epoch"] == 2
    assert meta["dispatches"] == 5 and meta["dropped"] == 0
    # comment-prefixed header/#@lat lines are invisible to Trace parsers
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        t = Trace.load(path)
    assert t.total() == 5
    # flush resets the window — the next epoch starts empty
    assert len(r) == 0 and r.total() == 0 and r.dropped == 0


def test_latency_reservoir_bounds_samples_keeps_observed_count(tmp_path):
    r = ShardRecorder("srv0", reservoir=8, seed=1)
    cell = OpCell("allreduce", 4, 512)
    for i in range(100):
        r.observe(cell, "allreduce_as_doubling", 1e-6 * (i + 1))
    r.append(_rec())
    path = r.flush(tmp_path, epoch=1)
    lat_lines = [ln for ln in path.read_text().splitlines()
                 if ln.startswith("#@lat ")]
    assert len(lat_lines) == 1
    m = json.loads(lat_lines[0][len("#@lat "):])
    assert len(m["lat_s"]) == 8            # reservoir bound
    assert m["observed"] == 100            # true sample count preserved
    back = load_shard_latencies(tmp_path)
    assert len(back[(cell, "allreduce_as_doubling")]) == 8


# ---------------------------------------------------------------------------
# merge_shards: fleet weight conservation
# ---------------------------------------------------------------------------


def test_merge_shards_preserves_total_weight(tmp_path):
    recs = [ShardRecorder(f"srv{i}") for i in range(3)]
    for i, r in enumerate(recs):
        for j in range(i + 1):
            r.append(_rec(nbytes=256 * (j + 1)))
            r.append(_rec(op="allgather", phase="decode"))
        r.flush(tmp_path, epoch=1)
    report = Trace.merge_shards(tmp_path)
    merged = report.trace
    assert len(report.merged) == 3 and not report.quarantined
    assert merged.total() == sum(i + 1 for i in range(3)) * 2
    assert merged.cells(phase="decode") == {OpCell("allgather", 4, 512): 6}


def test_merge_shards_empty_directory_warns_empty_report(tmp_path):
    # a cold-started fleet's first merge is a no-op, not a crash (the
    # old behavior raised FileNotFoundError); absent dir same deal
    with pytest.warns(UserWarning, match="cold start"):
        report = Trace.merge_shards(tmp_path)
    assert report.trace.total() == 0 and not report.shards
    with pytest.warns(UserWarning, match="no trace shards"):
        report = Trace.merge_shards(tmp_path / "never-created")
    assert report.trace.total() == 0 and len(report) == 0


def test_shard_digest_tracks_content(tmp_path):
    r = ShardRecorder("a")
    r.append(_rec())
    r.flush(tmp_path, epoch=1)
    d1 = shard_digest(tmp_path)
    assert d1.startswith("sha256:")
    r.append(_rec(nbytes=4096))
    r.flush(tmp_path, epoch=2)
    assert shard_digest(tmp_path) != d1


# ---------------------------------------------------------------------------
# MANIFEST + epochs
# ---------------------------------------------------------------------------


def _store(impl="allreduce_as_doubling", lo=1, hi=1 << 20):
    return ProfileStore([Profile(op="allreduce", axis_size=4,
                                 ranges=[Range(lo, hi, impl)])])


def test_manifest_roundtrip_with_census(tmp_path):
    write_manifest(tmp_path, 3, source_digest="sha256:abc",
                   base=_store(), phases={"decode": _store()})
    man = read_manifest(tmp_path)
    assert man["epoch"] == 3
    assert man["source"] == "sha256:abc"
    assert man["phases"] == {"decode": 1}
    assert man["geometry_census"]["allreduce"]["profiles"] == 2


def test_profile_store_save_with_epoch_writes_manifest(tmp_path):
    _store().save(tmp_path, epoch=5, source_digest="sha256:xyz")
    man = read_manifest(tmp_path)
    assert man["epoch"] == 5 and man["source"] == "sha256:xyz"
    # the MANIFEST must not be mistaken for a JSON profile on re-load
    back = ProfileStore.load(tmp_path)
    assert len(back) == 1


def test_manifest_demotions_roundtrip_through_poll(tmp_path):
    """The publishing process's demotion ledger rides MANIFEST.json and is
    re-applied when a FRESH process (empty ledger) adopts the epoch — a
    generation tuned with a wire impl excluded must not be served by a
    process that would route traffic back onto it."""
    C.clear_demotions()
    try:
        C.demote("allreduce", "wire_q8", "tolerance rel=0.5 > 0.063")
        _store().save(tmp_path, epoch=3)          # ledger snapshot rides along
        man = read_manifest(tmp_path)
        assert man["demotions"] == \
            [["allreduce", "wire_q8", "tolerance rel=0.5 > 0.063"]]

        C.clear_demotions()                       # the fresh serving process
        assert not C.is_demoted("allreduce", "wire_q8")
        ref = StoreRef(directory=tmp_path)
        assert ref.poll() and ref.epoch == 3
        assert C.is_demoted("allreduce", "wire_q8")
        reason = C.demotions()[("allreduce", "wire_q8")]
        assert reason.startswith("manifest: ")    # provenance is visible
    finally:
        C.clear_demotions()


def test_manifest_demotions_explicit_and_unknown_rows(tmp_path):
    """``demotions=`` overrides the ambient ledger; a row naming an impl
    this build doesn't know (a manifest from a newer build) is skipped
    with a warning, never fatal, and the rest still apply."""
    C.clear_demotions()
    try:
        _store().save(tmp_path)
        write_manifest(tmp_path, 4, base=_store(),
                       demotions={("allreduce", "wire_fp8"): "tol",
                                  ("allreduce", "no_such_impl"): "tol"})
        assert len(read_manifest(tmp_path)["demotions"]) == 2
        ref = StoreRef(directory=tmp_path)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            assert ref.poll()
        assert any("no_such_impl" in str(w.message) for w in caught)
        assert C.is_demoted("allreduce", "wire_fp8")
        assert not C.is_demoted("allreduce", "wire_q8")
    finally:
        C.clear_demotions()


def test_trace_tune_report_save_with_epoch(tmp_path):
    rep = tuner.TraceTuneReport(
        phase_profiles={"decode": _store()}, measurements=[],
        est_default_s={"decode": 1.0}, est_tuned_s={"decode": 0.5})
    rep.save(tmp_path, epoch=7, source_digest="sha256:s")
    man = read_manifest(tmp_path)
    assert man["epoch"] == 7 and man["phases"] == {"decode": 1}
    assert (tmp_path / "decode").is_dir()


# ---------------------------------------------------------------------------
# StoreRef: atomic swap, staleness, watch/poll
# ---------------------------------------------------------------------------


def test_store_ref_lookup_phase_over_base():
    ref = StoreRef(base=_store("implBase"),
                   phases={"decode": _store("implDecode")}, epoch=0)
    cell = OpCell("allreduce", 4, 512)
    assert ref.lookup(cell, "decode") == "implDecode"
    assert ref.lookup(cell, "prefill") == "implBase"


def test_store_ref_swap_refuses_stale_epoch():
    ref = StoreRef(base=_store("implA"), epoch=4)
    with pytest.warns(UserWarning, match="stale"):
        assert not ref.swap(_store("implB"), None, 3)
    assert ref.epoch == 4
    assert ref.lookup(OpCell("allreduce", 4, 512), "fwd") == "implA"
    assert not ref.swap(_store("implB"), None, 4)    # same epoch: no-op
    assert ref.swap(_store("implB"), None, 5)
    assert ref.lookup(OpCell("allreduce", 4, 512), "fwd") == "implB"


def test_store_ref_poll_adopts_new_epoch_and_refuses_regression(tmp_path):
    ref = StoreRef(directory=tmp_path)
    assert not ref.poll()                  # empty dir: nothing to adopt
    _store("implA").save(tmp_path, epoch=1)
    assert ref.poll()
    assert ref.epoch == 1
    assert ref.lookup(OpCell("allreduce", 4, 512), "fwd") == "implA"
    assert not ref.poll()                  # unchanged manifest: no re-stat
    # a delayed writer regressing the manifest must be refused
    write_manifest(tmp_path, 0)
    with pytest.warns(UserWarning, match="stale"):
        assert not ref.poll()
    assert ref.epoch == 1
    # a newer epoch lands: adopted
    _store("implB").save(tmp_path, epoch=2)
    assert ref.poll()
    assert ref.epoch == 2
    assert ref.lookup(OpCell("allreduce", 4, 512), "fwd") == "implB"


def test_store_ref_poll_adopts_legacy_manifestless_dir_once(tmp_path):
    _store("implA").save(tmp_path)         # no epoch, no MANIFEST
    ref = StoreRef(directory=tmp_path)
    assert ref.poll()
    assert ref.epoch == 0
    assert not ref.poll()                  # adopted once, not re-adopted


def test_resolve_stores_watch_mode_returns_ref(tmp_path, monkeypatch):
    _store("implA").save(tmp_path, epoch=4)
    monkeypatch.setenv("PGTUNE_PROFILE_DIR", str(tmp_path))
    ref = resolve_stores(watch=True)
    assert isinstance(ref, StoreRef)
    assert ref.epoch == 4                  # first poll happens at resolve
    assert ref.lookup(OpCell("allreduce", 4, 512), "fwd") == "implA"


def test_resolve_stores_watch_mode_unset_env(monkeypatch):
    monkeypatch.delenv("PGTUNE_PROFILE_DIR", raising=False)
    ref = resolve_stores(watch=True)
    assert ref.epoch == -1 and not ref.poll()


# ---------------------------------------------------------------------------
# Plan: stable slots, capacity, vectors, exploration
# ---------------------------------------------------------------------------


def test_plan_slots_stable_across_reregistration():
    plan = api.Plan(capacity=8)
    cell = OpCell("allreduce", 4, 512)
    impls = ("default", "a", "b")
    s = plan.slot(cell, "fwd", impls)
    assert plan.slot(cell, "fwd", impls) == s      # recompilation: same slot
    assert plan.slot(cell, "bwd", impls) == s + 1  # new phase: new site
    # admissible-set drift disables the site rather than mis-indexing
    assert plan.slot(cell, "fwd", ("default", "a")) is None


def test_plan_capacity_exhaustion_returns_none():
    plan = api.Plan(capacity=2)
    impls = ("default", "a")
    assert plan.slot(OpCell("allreduce", 4, 8), "fwd", impls) == 0
    assert plan.slot(OpCell("allreduce", 4, 16), "fwd", impls) == 1
    assert plan.slot(OpCell("allreduce", 4, 32), "fwd", impls) is None
    assert len(plan) == 2


def test_plan_vector_resolves_through_stores_and_ref():
    plan = api.Plan(capacity=4)
    cell = OpCell("allreduce", 4, 512)
    impls = ("default", "allreduce_as_doubling", "allreduce_as_rsb_allgather")
    s = plan.slot(cell, "decode", impls)
    vec = plan.vector(base=_store("allreduce_as_doubling"))
    assert vec.dtype == np.int32 and vec.shape == (4,)
    assert vec[s] == 1
    # unknown selection (not admissible at this site) falls back to 0
    assert plan.vector(base=_store("not_an_impl"))[s] == 0
    ref = StoreRef(phases={"decode": _store("allreduce_as_rsb_allgather")},
                   epoch=1)
    assert plan.vector(ref)[s] == 2
    assert plan.vector()[s] == 0           # no stores: default


def test_plan_explore_flips_to_cyclic_next():
    plan = api.Plan(capacity=4)
    cell = OpCell("allreduce", 4, 512)
    impls = ("default", "allreduce_as_doubling", "allreduce_as_rsb_allgather")
    s = plan.slot(cell, "fwd", impls)
    rng = np.random.default_rng(0)
    vec, explored = plan.explore(eps=1.0, rng=rng,
                                 base=_store("allreduce_as_doubling"))
    assert vec[s] == 2                     # 1 -> next in the ring
    assert explored[(cell, "fwd")] == "allreduce_as_rsb_allgather"
    vec0, explored0 = plan.explore(eps=0.0, rng=rng,
                                   base=_store("allreduce_as_doubling"))
    assert vec0[s] == 1 and not explored0  # eps=0: pure exploitation


# ---------------------------------------------------------------------------
# runtime dispatch: the hot swap happens with ZERO re-jits
# ---------------------------------------------------------------------------


P = 4


@pytest.fixture
def probe_impl(monkeypatch):
    """A marker impl whose output is distinguishable from any real
    allreduce — proof of which switch branch RAN (not which was traced)."""
    probe = C.Impl(name="probe_marker", op="allreduce",
                   fn=lambda x, axis, **kw: jnp.full_like(x, 42.0),
                   guideline="EXT", extra_bytes=lambda n, p: 0)
    monkeypatch.setitem(C.REGISTRY["allreduce"], "probe_marker", probe)
    return probe


def test_plan_dispatch_switches_impl_at_runtime_zero_retrace(probe_impl):
    plan = api.Plan(capacity=8)
    ref = StoreRef()

    def step(x, vec):
        with api.plan_input(vec):
            return api.allreduce(x, "ax")

    f = jax.jit(jax.vmap(step, axis_name="ax", in_axes=(0, None)))
    x = jnp.ones((P, 4), jnp.float32)
    with api.tuned(store_ref=ref, plan=plan):
        out0 = f(x, jnp.zeros(plan.capacity, jnp.int32))
        assert f._cache_size() == 1
        sites = plan.sites()
        assert len(sites) == 1
        cell, phase, impls = sites[0]
        assert "probe_marker" in impls
        np.testing.assert_allclose(out0, np.full((P, 4), float(P)))

        # hot-swap: a generation that selects the probe impl
        ref.swap(ProfileStore([Profile(op="allreduce", axis_size=P,
                                       ranges=[Range(1, 1 << 20,
                                                     "probe_marker")])]),
                 None, epoch=1)
        vec1 = jnp.asarray(plan.vector(ref))
        assert int(vec1.sum()) > 0
        out1 = f(x, vec1)
        np.testing.assert_allclose(out1, np.full((P, 4), 42.0))
        # the defining property: the impl CHANGED, the jit cache did not
        assert f._cache_size() == 1


def test_plan_dispatch_all_real_impls_agree_under_vmap():
    """Every admissible branch of the runtime switch is a correct
    allreduce: cycling the plan vector through all of them must
    reproduce the default's numbers (and never re-trace)."""
    plan = api.Plan(capacity=8)

    def step(x, vec):
        with api.plan_input(vec):
            return api.allreduce(x, "ax")

    f = jax.jit(jax.vmap(step, axis_name="ax", in_axes=(0, None)))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(P, 8)), jnp.float32)
    from repro.core import collectives as C
    from repro.core.selfcheck import rel_err, wire_hops
    from repro.kernels.quant import wire_tol
    with api.tuned(store_ref=StoreRef(), plan=plan):
        ref_out = f(x, jnp.zeros(plan.capacity, jnp.int32))
        ((_cell, _ph, impls),) = plan.sites()
        for i in range(1, len(impls)):
            vec = np.zeros(plan.capacity, np.int32)
            vec[0] = i
            out = f(x, jnp.asarray(vec))
            wd = C.REGISTRY["allreduce"][impls[i]].wire_dtype
            if wd is not None:
                # quantized-wire branches are approximate: gate at their
                # selfcheck tolerance, not exact agreement
                assert rel_err(out, ref_out) <= wire_tol(
                    wd, wire_hops("allreduce", P)), impls[i]
            else:
                np.testing.assert_allclose(out, ref_out, rtol=2e-5,
                                           err_msg=impls[i])
        assert f._cache_size() == 1


def test_plan_dispatch_respects_force_and_static_fallback(probe_impl):
    """Forced ops and capacity-exhausted sites bypass the plan: they
    dispatch statically like before (recorded with their real impl, not
    the 'plan' marker)."""
    plan = api.Plan(capacity=0)            # no capacity: every site static

    def step(x, vec):
        with api.plan_input(vec):
            return api.allreduce(x, "ax")

    f = jax.jit(jax.vmap(step, axis_name="ax", in_axes=(0, None)))
    x = jnp.ones((P, 2), jnp.float32)
    with api.tuned(store_ref=StoreRef(), plan=plan) as ctx:
        out = f(x, jnp.zeros(4, jnp.int32))
    np.testing.assert_allclose(out, np.full((P, 2), float(P)))
    assert len(plan) == 0
    assert [r.impl for r in ctx.record] == ["default"]

    plan2 = api.Plan(capacity=8)
    with api.tuned(force={"allreduce": "probe_marker"}, plan=plan2) as ctx2:
        out2 = jax.jit(jax.vmap(
            lambda x, v: step(x, v), axis_name="ax",
            in_axes=(0, None)))(x, jnp.zeros(8, jnp.int32))
    np.testing.assert_allclose(out2, np.full((P, 2), 42.0))
    assert len(plan2) == 0                 # forced op never joins the plan
    assert [r.impl for r in ctx2.record] == ["probe_marker"]


def test_plan_dispatch_records_plan_marker(probe_impl):
    plan = api.Plan(capacity=8)

    def step(x, vec):
        with api.plan_input(vec):
            return api.allreduce(x, "ax")

    with api.tuned(store_ref=StoreRef(), plan=plan) as ctx:
        jax.jit(jax.vmap(step, axis_name="ax", in_axes=(0, None)))(
            jnp.ones((P, 2), jnp.float32), jnp.zeros(8, jnp.int32))
    assert [r.impl for r in ctx.record] == [api.PLAN_IMPL]


# ---------------------------------------------------------------------------
# feedback: exploration measurements drive the next epoch
# ---------------------------------------------------------------------------


def test_feedback_backend_overrides_with_observed_median():
    from repro.core import costmodel
    base = tuner.CostModelBackend(costmodel.V5E_ICI)
    cell = OpCell("allreduce", 4, 4096)
    obs = {(cell, "default"): [3e-6, 1e-6, 2e-6]}
    fb = FeedbackBackend(base, obs, min_samples=3)
    assert fb.latency(cell, "default") == 2e-6            # median
    assert fb.nrep_for(cell, "default") == 3
    # under-sampled pairs and unseen cells fall back to the base model
    fb2 = FeedbackBackend(base, obs, min_samples=5)
    assert fb2.latency(cell, "default") == base.latency(cell, "default")
    other = OpCell("allreduce", 4, 128)
    assert fb.latency(other, "default") == base.latency(other, "default")


def test_estimate_trace_cost_prices_profile_selection():
    from repro.core import costmodel
    backend = tuner.CostModelBackend(costmodel.V5E_ICI)
    t = Trace([TraceEntry.of("allreduce", 16, 1 << 20, "decode", count=10)])
    untuned = estimate_trace_cost(t, backend)
    rep = tuner.tune_trace(t, backend=backend)
    tuned = estimate_trace_cost(t, backend, phases=rep.phase_profiles)
    assert set(untuned) == {"decode"}
    if rep.phase_profiles:                 # a violation was found
        assert tuned["decode"] < untuned["decode"]
    # an inadmissible selection silently degrades to the default price
    bad = {"decode": _store("not_an_impl", hi=1 << 30)}
    cell16 = Trace([TraceEntry.of("allreduce", 16, 1 << 20, "decode")])
    assert (estimate_trace_cost(cell16, backend, phases=bad)["decode"]
            == estimate_trace_cost(cell16, backend)["decode"])
