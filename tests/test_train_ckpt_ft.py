"""Trainer behaviour, checkpoint/restart fault tolerance, watchdog, data."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ck
from repro.configs import get_config
from repro.data import make_batch
from repro.ft import StepWatchdog, run_with_restarts
from repro.models import lm
from repro.models.params import init_tree
from repro.train import Trainer


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3.2-3b").smoke()
    tr = Trainer(cfg, mesh=None, n_micro=1, base_lr=3e-3, warmup=5)
    params, opt = tr.init(0)
    return cfg, tr, params, opt


def test_loss_decreases(tiny):
    cfg, tr, params, opt = tiny
    params, opt = jax.tree.map(jnp.copy, (params, opt))  # step() donates
    losses = []
    for i in range(25):
        batch = tr.put_batch(make_batch(cfg, 8, 32, i))
        params, opt, m = tr.step(params, opt, batch, i)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    cfg = get_config("llama3.2-3b").smoke()
    tr1 = Trainer(cfg, mesh=None, n_micro=1)
    tr4 = Trainer(cfg, mesh=None, n_micro=4)
    p1, o1 = tr1.init(3)
    p4, o4 = jax.tree.map(jnp.copy, (p1, o1))
    batch = tr1.put_batch(make_batch(cfg, 8, 32, 0))
    p1, o1, m1 = tr1.step(p1, o1, batch, 0)
    p4, o4, m4 = tr4.step(p4, o4, batch, 0)
    # losses are means over the same tokens; grads averaged over microbatches
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-2, d


def test_data_determinism_and_sharding():
    cfg = get_config("llama3.2-3b").smoke()
    a = make_batch(cfg, 8, 32, step=7)
    b = make_batch(cfg, 8, 32, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, 8, 32, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    s0 = make_batch(cfg, 8, 32, step=7, shard=0, n_shards=2)
    s1 = make_batch(cfg, 8, 32, step=7, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_ckpt_roundtrip(tmp_path, tiny):
    cfg, tr, params, opt = tiny
    state = {"params": params, "opt": opt, "step": jnp.int32(5)}
    ck.save(tmp_path, 5, state)
    assert ck.latest_step(tmp_path) == 5
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    back = ck.restore(tmp_path, 5, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_gc_keep(tmp_path, tiny):
    cfg, tr, params, opt = tiny
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, {"p": params}, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(9))


def test_async_checkpointer(tmp_path, tiny):
    cfg, tr, params, opt = tiny
    acp = ck.AsyncCheckpointer(tmp_path)
    acp.save(1, {"p": params})
    acp.save(2, {"p": params})
    acp.wait()
    assert ck.latest_step(tmp_path) == 2


def test_restart_resumes_and_is_deterministic(tmp_path):
    """Inject failures; the restart driver must resume from the newest
    checkpoint and reach the same final state as a failure-free run."""
    cfg = get_config("llama3.2-3b").smoke()
    tr = Trainer(cfg, mesh=None)

    def init_state():
        params, opt = tr.init(0)
        return {"params": params, "opt": opt}

    def make_step(faults: set):
        calls = {"n": 0}

        def step_fn(state, i):
            calls["n"] += 1
            if i in faults and faults.pop(i) is not None:
                raise RuntimeError("injected node failure")
            batch = tr.put_batch(make_batch(cfg, 4, 16, i))
            p, o, _ = tr.step(state["params"], state["opt"], batch, i)
            return {"params": p, "opt": o}
        return step_fn

    final_a, stats_a = run_with_restarts(
        init_state, make_step({7: 1, 13: 1}), n_steps=16,
        ckpt_dir=tmp_path / "a", ckpt_every=5)
    assert stats_a["restarts"] == 2
    assert stats_a["resumed_from"] == [5, 10]

    final_b, stats_b = run_with_restarts(
        init_state, make_step(set()), n_steps=16,
        ckpt_dir=tmp_path / "b", ckpt_every=5)
    assert stats_b["restarts"] == 0

    for a, b in zip(jax.tree.leaves(final_a["params"]),
                    jax.tree.leaves(final_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(ratio=3.0)
    for i in range(10):
        wd.start_step()
        time.sleep(0.002)
        assert not wd.end_step()
    wd.start_step()
    time.sleep(0.05)
    assert wd.end_step()
    assert wd.straggler_steps == [10]


def test_watchdog_hang_timer_fires():
    import threading, time
    fired = threading.Event()
    wd = StepWatchdog(hang_timeout=0.05, on_hang=fired.set)
    wd.start_step()
    time.sleep(0.15)
    assert fired.is_set()
    wd.end_step()


# ---------------------------------------------------------------------------
# elastic: profiles are re-keyed per axis size (the paper's validity rule)
# ---------------------------------------------------------------------------


def test_elastic_profile_rekey():
    from repro.core import costmodel as cm
    from repro.core import tuner
    rep16 = tuner.tune(ops=["allreduce"], axis_size=16,
                       backend=tuner.CostModelBackend(cm.BGQ_LIKE))
    store = rep16.profiles
    # a resize to 12 devices must NOT use the p=16 profile
    assert store.lookup("allreduce", 16, 1024) is not None
    assert store.lookup("allreduce", 12, 1024) is None
