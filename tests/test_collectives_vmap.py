"""Semantic equivalence of every mock-up vs the dense numpy oracle.

Runs under vmap(axis_name=...) — single device, exact same code path the
production shard_map uses (tests/test_spmd_subprocess.py covers real SPMD
lowering on 8 host devices).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import collectives as C
from repro.core.selfcheck import rel_err, wire_hops
from repro.kernels.quant import wire_tol

PS = (4, 8)
DTYPES = (np.float32, np.int32)


def run(fn, x, p, **kw):
    return np.asarray(
        jax.vmap(lambda a: fn(a, "x", **kw), axis_name="x")(jnp.asarray(x)))


def run_hier(fn, x, p, **kw):
    """Hierarchical mock-ups need a nested mesh: split the p ranks into
    (p/2 outer, 2 inner) — outer-major, so the joint group order matches
    the flat stack and the same oracle applies."""
    nested = jnp.asarray(x).reshape((p // 2, 2) + x.shape[1:])
    out = jax.vmap(jax.vmap(lambda a: fn(a, "x", inner_axis="y", **kw),
                            axis_name="y"), axis_name="x")(nested)
    return np.asarray(out).reshape((p,) + out.shape[2:])


def run_any(op, name, x, p, **kw):
    fn = C.REGISTRY[op][name].fn
    if C.REGISTRY[op][name].hier:
        return run_hier(fn, x, p, **kw)
    return run(fn, x, p, **kw)


def data(rng, p, rows, width=3, dtype=np.float32):
    if np.issubdtype(dtype, np.integer):
        return rng.integers(-50, 50, size=(p, rows, width)).astype(dtype)
    return rng.normal(size=(p, rows, width)).astype(dtype)


def assert_close(op, name, p, got, want, atol):
    """Exact atol for lossless impls; the selfcheck wire tolerance (max-norm
    relative, hop-scaled) for quantized-wire mock-ups."""
    wd = C.REGISTRY[op][name].wire_dtype
    if wd is None:
        np.testing.assert_allclose(got, want, atol=atol)
    else:
        r = rel_err(got, want)
        assert r <= wire_tol(wd, wire_hops(op, p)), (op, name, r)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", C.impl_names("allgather"))
def test_allgather(rng, p, dtype, name):
    if (C.REGISTRY["allgather"][name].wire_dtype is not None
            and np.issubdtype(dtype, np.integer)):
        pytest.skip("quantized wire targets float payloads")
    x = data(rng, p, 5, dtype=dtype)
    want = x.reshape(p * 5, 3)
    got = run_any("allgather", name, x, p)
    assert_close("allgather", name, p, got,
                 np.broadcast_to(want, (p,) + want.shape), 1e-5)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("name", C.impl_names("allreduce"))
@pytest.mark.parametrize("chunk", (1, 3))
def test_allreduce(rng, p, name, chunk):
    x = data(rng, p, 7)
    got = run_any("allreduce", name, x, p, chunk=chunk)
    assert_close("allreduce", name, p, got,
                 np.broadcast_to(x.sum(0), (p, 7, 3)), 1e-4)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("name", C.impl_names("reducescatter"))
def test_reducescatter(rng, p, name):
    x = data(rng, p, p * 4)
    want = x.sum(0).reshape(p, 4, 3)
    got = run_any("reducescatter", name, x, p)
    assert_close("reducescatter", name, p, got, want, 1e-4)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("name", C.impl_names("alltoall"))
def test_alltoall(rng, p, name):
    x = data(rng, p, p * 2)
    want = x.reshape(p, p, 2, 3).transpose(1, 0, 2, 3).reshape(p, p * 2, 3)
    got = run(C.REGISTRY["alltoall"][name].fn, x, p)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("root", (0, 2))
@pytest.mark.parametrize("name", C.impl_names("bcast"))
def test_bcast(rng, p, root, name):
    x = data(rng, p, 5)
    got = run(C.REGISTRY["bcast"][name].fn, x, p, root=root)
    np.testing.assert_allclose(got, np.broadcast_to(x[root], (p, 5, 3)),
                               atol=1e-5)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("root", (0, 3))
@pytest.mark.parametrize("name", C.impl_names("gather"))
def test_gather_root_only(rng, p, root, name):
    x = data(rng, p, 5)
    got = run(C.REGISTRY["gather"][name].fn, x, p, root=root)
    np.testing.assert_allclose(got[root], x.reshape(p * 5, 3), atol=1e-5)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("root", (0, 3))
@pytest.mark.parametrize("name", C.impl_names("scatter"))
def test_scatter(rng, p, root, name):
    x = data(rng, p, p * 5)
    got = run(C.REGISTRY["scatter"][name].fn, x, p, root=root)
    np.testing.assert_allclose(got, x[root].reshape(p, 5, 3), atol=1e-5)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("root", (0, 1))
@pytest.mark.parametrize("name", C.impl_names("reduce"))
def test_reduce_root_only(rng, p, root, name):
    x = data(rng, p, 6)
    got = run(C.REGISTRY["reduce"][name].fn, x, p, root=root, chunk=2)
    np.testing.assert_allclose(got[root], x.sum(0), atol=1e-4)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("name", C.impl_names("scan"))
def test_scan(rng, p, name):
    x = data(rng, p, 4)
    got = run(C.REGISTRY["scan"][name].fn, x, p)
    np.testing.assert_allclose(got, np.cumsum(x, axis=0), atol=1e-5)


@pytest.mark.parametrize("p", PS)
def test_exscan(rng, p):
    x = data(rng, p, 4)
    got = run(C.REGISTRY["exscan"]["default"].fn, x, p)
    want = np.cumsum(x, axis=0) - x
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_scan_max(rng):
    p = 8
    x = data(rng, p, 4)
    got = run(C.REGISTRY["scan"]["default"].fn, x, p, op="max")
    np.testing.assert_allclose(got, np.maximum.accumulate(x, axis=0),
                               atol=1e-6)
