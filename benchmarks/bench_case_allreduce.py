"""Paper §4.4.2 / Fig. 7: the Allreduce mock-up that beat every library
algorithm.

Cast of characters, re-derived on the naive fabric at 512 procs:
  Default                   — the library's tree reduce+bcast
  MCA_nonoverlapping        — reduce + bcast ('allreduce_as_tree_reduce_bcast')
  Reduce_scatter+Allgatherv — GL7 mock-up (the winner)
  MCA_NEW_...               — GL7 promoted to the default (the paper's
                              upstreamed Open MPI patch): identical latency.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import costmodel as cm
from repro.core import tuner

P = 512
NAIVE = cm.Topo("jupiter-naive", alpha=1.3e-6, link_bw=5e9, gamma=4e-12,
                default_pricing="naive")


def run():
    winner_everywhere = True
    for nbytes in (1_048_576, 4_194_304, 16_777_216):
        rows = {
            "default": cm.latency("allreduce", "default", P, nbytes, NAIVE),
            "mca_nonoverlapping": cm.latency(
                "allreduce", "allreduce_as_tree_reduce_bcast", P, nbytes,
                NAIVE),
            "gl6_rsb_allgather": cm.latency(
                "allreduce", "allreduce_as_rsb_allgather", P, nbytes, NAIVE),
            "gl7_rs_allgatherv": cm.latency(
                "allreduce", "allreduce_as_rs_allgatherv", P, nbytes, NAIVE),
        }
        # the upstreamed algorithm == the mock-up's schedule
        rows["mca_new_rs_agv"] = rows["gl7_rs_allgatherv"]
        best = min(rows, key=rows.get)
        winner_everywhere &= best in ("gl7_rs_allgatherv", "mca_new_rs_agv",
                                      "gl6_rsb_allgather")
        for name, t in rows.items():
            emit(f"fig7/{name}/{nbytes}B", t * 1e6,
                 "WINNER" if name == best else "")
    emit("fig7/rs_ag_wins_bandwidth_regime", 0.0, str(winner_everywhere))

    # and the tuner discovers it automatically:
    rep = tuner.tune(ops=["allreduce"], axis_size=P,
                     backend=tuner.CostModelBackend(NAIVE))
    prof = rep.profiles.get("allreduce", P)
    picks = {r.impl for r in prof.ranges} if prof else set()
    emit("fig7/tuner_selects_rs_ag", 0.0, ";".join(sorted(picks)))


if __name__ == "__main__":
    run()
