"""Fused-vs-unfused collective-matmul latency per shape (modeled) + the
must-win consistency check.

For a grid of (op, p, nbytes) cells, price the unfused composition and the
``fused_ring`` overlap schedule on the v5e ICI model, then run the tuner on
the same grid and verify its selections agree: every cell where the overlap
model says fusion wins by at least ``MIN_WIN`` must select ``fused_ring``,
and at least one small cell must keep the default (fusion's per-step
overhead must not be modeled away).  Emits ``BENCH_collective_matmul.json``
for the CI artifact; exits non-zero (via ``run()`` raising) when the tuner
never selects the fused impl on a must-win shape.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.core import costmodel as cm
from repro.core import tuner

OPS = ("allgather_matmul", "matmul_reducescatter", "matmul_accumulate")
AXIS_SIZES = (4, 8, 16, 64)
SIZES = (64, 1024, 32768, 262_144, 1_048_576, 4_194_304, 16_777_216)
MIN_WIN = 0.10
OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / \
    "BENCH_collective_matmul.json"


def sweep_cells(topo=cm.V5E_ICI):
    cells = []
    for op in OPS:
        for p in AXIS_SIZES:
            rep = tuner.tune(ops=[op], sizes=SIZES, axis_size=p,
                             backend=tuner.CostModelBackend(topo),
                             min_win=MIN_WIN)
            for nbytes in SIZES:
                t_def = cm.latency(op, "default", p, nbytes, topo)
                t_fus = cm.latency(op, "fused_ring", p, nbytes, topo)
                pick = rep.profiles.lookup(op, p, nbytes) or "default"
                cells.append({"op": op, "p": p, "nbytes": nbytes,
                              "t_default_s": t_def, "t_fused_s": t_fus,
                              "model_win": t_def / t_fus,
                              "tuner_pick": pick})
    return cells


def run():
    cells = sweep_cells()
    must_win = [c for c in cells if c["t_fused_s"]
                < c["t_default_s"] * (1.0 - MIN_WIN)]
    missed = [c for c in must_win if c["tuner_pick"] != "fused_ring"]
    n_fused = sum(1 for c in cells if c["tuner_pick"] == "fused_ring")
    n_default_small = sum(1 for c in cells
                          if c["nbytes"] <= 1024
                          and c["tuner_pick"] == "default")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({
        "min_win": MIN_WIN, "cells": cells,
        "must_win_cells": len(must_win), "missed": missed,
    }, indent=1))
    for op in OPS:
        best = max((c["model_win"] for c in cells if c["op"] == op),
                   default=0.0)
        emit(f"collective_matmul/{op}", 0.0,
             f"fused_selected={sum(1 for c in cells if c['op'] == op and c['tuner_pick'] == 'fused_ring')}"
             f"/{sum(1 for c in cells if c['op'] == op)}"
             f" best_model_win=x{best:.2f}")
    if missed:
        raise AssertionError(
            f"tuner missed {len(missed)} must-win fused cells, e.g. "
            f"{missed[0]}")
    if not must_win or n_fused == 0:
        raise AssertionError("overlap model never favors fused_ring — "
                             "cost model regression")
    if n_default_small == 0:
        raise AssertionError("fused_ring selected even on tiny messages — "
                             "per-step overhead lost from the model")
    emit("collective_matmul/consistency", 0.0,
         f"must_win={len(must_win)} missed=0 json={OUT.name}")


def main():
    run()
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
