"""Fused-vs-unfused collective-matmul latency per shape (modeled) + the
must-win consistency check.

For a grid of (op, p, nbytes) cells, price the unfused composition and the
``fused_ring`` overlap schedule on the v5e ICI model, then run the tuner on
the same grid and verify its selections agree: every cell where the overlap
model says fusion wins by at least ``MIN_WIN`` must select ``fused_ring``,
and at least one small cell must keep the default (fusion's per-step
overhead must not be modeled away).

The 2-D section does the same over data x model MESHES with geometry
cells: per (d, q, GEMM) cell it prices THREE alternatives — the unfused
composition, the 1-D status quo (data-gather fused + monolithic model
allreduce — what ``row_matmul(fsdp_dim=1)`` emitted before the 2-D op)
and the nested ``fused_ring2d`` — replays the cells through
``tuner.tune_trace`` and verifies the per-cell selection matches every
modeled must-win.  Emits ``BENCH_collective_matmul.json`` for the CI
artifact; exits non-zero (via ``run()`` raising) when the tuner misses a
must-win shape in either section.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.core import costmodel as cm
from repro.core import tuner
from repro.core.cell import OpCell
from repro.core.trace import Trace, TraceEntry

OPS = ("allgather_matmul", "matmul_reducescatter", "matmul_accumulate")
AXIS_SIZES = (4, 8, 16, 64)
SIZES = (64, 1024, 32768, 262_144, 1_048_576, 4_194_304, 16_777_216)
MIN_WIN = 0.10
#: 2-D section: (data, model) meshes x per-callsite GEMMs (T, K, M) — the
#: row_matmul(fsdp_dim=1) w_out shapes of serving-sized LMs, plus slivers
#: that must keep the default
MESHES_2D = ((2, 2), (4, 4), (8, 8), (16, 8))
GEMMS_2D = ((8192, 4096, 14336),      # mlp w_out, prefill batch
            (1024, 4096, 4096),       # attention w_o
            (256, 14336, 4096),       # mlp w_out, small decode batch
            (8, 512, 256))            # sliver: overhead must win
OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / \
    "BENCH_collective_matmul.json"


def sweep_cells(topo=cm.V5E_ICI):
    cells = []
    for op in OPS:
        for p in AXIS_SIZES:
            rep = tuner.tune(ops=[op], sizes=SIZES, axis_size=p,
                             backend=tuner.CostModelBackend(topo),
                             min_win=MIN_WIN)
            for nbytes in SIZES:
                t_def = cm.latency(op, "default", p, nbytes, topo)
                t_fus = cm.latency(op, "fused_ring", p, nbytes, topo)
                pick = rep.profiles.lookup(op, p, nbytes) or "default"
                cells.append({"op": op, "p": p, "nbytes": nbytes,
                              "t_default_s": t_def, "t_fused_s": t_fus,
                              "model_win": t_def / t_fus,
                              "tuner_pick": pick})
    return cells


def _cell_2d(d: int, q: int, t: int, k: int, m: int) -> OpCell:
    """The dispatch cell row_matmul(fsdp_dim=1) records on a (d, q) mesh
    for the logical GEMM [t, k] @ [k, m]: per-rank dims, payload = the
    streamed weight column block [k/q, m/d]."""
    k_loc, m_loc = max(1, k // q), max(1, m // d)
    return OpCell("matmul_reducescatter_2d", d, k_loc * m_loc * 4,
                  "float32", mm_k=k_loc, mm_m=t, mm_n=d * m_loc,
                  mm_role="2d", p2=q)


def sweep_cells_2d(topo=cm.V5E_ICI):
    """Three-way modeled comparison per 2-D cell: unfused vs the 1-D
    status quo (fsdp_matmul fused + monolithic model-axis allreduce) vs
    the nested 2-D schedule, plus the trace-tuner's per-cell pick."""
    rows = []
    entries = []
    for d, q in MESHES_2D:
        for t, k, m in GEMMS_2D:
            cell = _cell_2d(d, q, t, k, m)
            entries.append(TraceEntry(cell, "fwd", "default", 1))
    rep = tuner.tune_trace(Trace(entries),
                           backend=tuner.CostModelBackend(topo),
                           min_win=MIN_WIN)
    store = rep.store("fwd")
    for d, q in MESHES_2D:
        for t, k, m in GEMMS_2D:
            cell = _cell_2d(d, q, t, k, m)
            t_unf = cm.latency_cell(cell, "default", topo)
            t_2d = cm.latency_cell(cell, "fused_ring2d", topo)
            # 1-D status quo: the data-axis weight gather fused
            # (allgather_matmul with the weight as the gathered operand,
            # fsdp_matmul's formulation) + an unfused model allreduce of
            # the [t, m] partial products
            agmm = OpCell("allgather_matmul", d, cell.nbytes, "float32",
                          mm_k=cell.mm_k, mm_m=cell.mm_n, mm_n=t,
                          mm_role="gather")
            t_1d = (cm.latency_cell(agmm, "fused_ring", topo)
                    + cm.latency("allreduce", "default", q, t * m * 4,
                                 topo))
            # every cell here was IN the trace, so the tuner's per-cell
            # verdict is its EXACT geometry profile (the nearest-geometry
            # fallback is for unseen shapes and would leak big-cell wins
            # onto slivers)
            prof = store.get("matmul_reducescatter_2d", d,
                             cell.geom()) if store else None
            pick = (prof.lookup(cell.nbytes) if prof else None) or "default"
            best = min(("default", t_unf), ("fused_1d", t_1d),
                       ("fused_ring2d", t_2d), key=lambda kv: kv[1])[0]
            rows.append({"d": d, "q": q, "gemm": [t, k, m],
                         "nbytes": cell.nbytes,
                         "t_unfused_s": t_unf, "t_fused1d_s": t_1d,
                         "t_fused2d_s": t_2d,
                         "model_win_vs_unfused": t_unf / t_2d,
                         "model_win_vs_1d": t_1d / t_2d,
                         "modeled_best": best, "tuner_pick": pick})
    return rows


def run():
    cells = sweep_cells()
    must_win = [c for c in cells if c["t_fused_s"]
                < c["t_default_s"] * (1.0 - MIN_WIN)]
    missed = [c for c in must_win if c["tuner_pick"] != "fused_ring"]
    n_fused = sum(1 for c in cells if c["tuner_pick"] == "fused_ring")
    n_default_small = sum(1 for c in cells
                          if c["nbytes"] <= 1024
                          and c["tuner_pick"] == "default")
    cells_2d = sweep_cells_2d()
    must_win_2d = [c for c in cells_2d
                   if c["t_fused2d_s"] < min(c["t_unfused_s"],
                                             c["t_fused1d_s"])
                   * (1.0 - MIN_WIN)]
    missed_2d = [c for c in must_win_2d
                 if c["tuner_pick"] != "fused_ring2d"]
    n_default_2d = sum(1 for c in cells_2d if c["tuner_pick"] == "default")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({
        "min_win": MIN_WIN, "cells": cells,
        "must_win_cells": len(must_win), "missed": missed,
        "cells_2d": cells_2d, "must_win_cells_2d": len(must_win_2d),
        "missed_2d": missed_2d,
    }, indent=1))
    for op in OPS:
        best = max((c["model_win"] for c in cells if c["op"] == op),
                   default=0.0)
        emit(f"collective_matmul/{op}", 0.0,
             f"fused_selected={sum(1 for c in cells if c['op'] == op and c['tuner_pick'] == 'fused_ring')}"
             f"/{sum(1 for c in cells if c['op'] == op)}"
             f" best_model_win=x{best:.2f}")
    n2 = len(cells_2d)
    n2_fused = sum(1 for c in cells_2d if c["tuner_pick"] == "fused_ring2d")
    best_2d = max((c["model_win_vs_unfused"] for c in cells_2d),
                  default=0.0)
    emit("collective_matmul/matmul_reducescatter_2d", 0.0,
         f"fused_selected={n2_fused}/{n2} best_model_win=x{best_2d:.2f} "
         f"must_win_vs_both={len(must_win_2d)}")
    if missed:
        raise AssertionError(
            f"tuner missed {len(missed)} must-win fused cells, e.g. "
            f"{missed[0]}")
    if not must_win or n_fused == 0:
        raise AssertionError("overlap model never favors fused_ring — "
                             "cost model regression")
    if n_default_small == 0:
        raise AssertionError("fused_ring selected even on tiny messages — "
                             "per-step overhead lost from the model")
    if missed_2d:
        raise AssertionError(
            f"tuner missed {len(missed_2d)} must-win 2-D fused cells "
            f"(vs BOTH the unfused and 1-D compositions), e.g. "
            f"{missed_2d[0]}")
    if not must_win_2d:
        raise AssertionError("nested-overlap model never beats both the "
                             "unfused and 1-D compositions — 2-D cost "
                             "model regression")
    if n_default_2d == 0:
        raise AssertionError("fused_ring2d selected even on sliver GEMMs — "
                             "the per-step overhead on both axes is lost "
                             "from the model")
    emit("collective_matmul/consistency", 0.0,
         f"must_win={len(must_win)} missed=0 must_win_2d={len(must_win_2d)} "
         f"missed_2d=0 json={OUT.name}")


def main():
    run()
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
