"""Fused-vs-unfused collective-matmul latency per shape (modeled) + the
must-win consistency check.

For a grid of (op, p, nbytes) cells, price the unfused composition and the
``fused_ring`` overlap schedule on the v5e ICI model, then run the tuner on
the same grid and verify its selections agree: every cell where the overlap
model says fusion wins by at least ``MIN_WIN`` must select ``fused_ring``,
and at least one small cell must keep the default (fusion's per-step
overhead must not be modeled away).

The 2-D section does the same over data x model MESHES with geometry
cells: per (d, q, GEMM) cell it prices THREE alternatives — the unfused
composition, the 1-D status quo (data-gather fused + monolithic model
allreduce — what ``row_matmul(fsdp_dim=1)`` emitted before the 2-D op)
and the nested ``fused_ring2d`` — replays the cells through
``tuner.tune_trace`` and verifies the per-cell selection matches every
modeled must-win.

The quantized-wire section re-prices the fused grid at DCN-tier
bandwidth, where the wire bytes are the bill: every comm-bound cell
whose best 8-bit wire impl models >= ``WIRE_MUST_WIN``x over the same
cell's f32-wire ``fused_ring`` must be SELECTED as a wire impl by the
tuner, slivers must keep the default, and every selected wire impl must
pass the selfcheck numeric-tolerance gate (``selfcheck.run_gate``) with
an empty demotion ledger.  The swept cells are also written as a
schema-v2 trace artifact and reloaded with ``DeprecationWarning``
promoted to an error (the v1-sunset check on newly-produced artifacts).

Emits ``BENCH_collective_matmul.json`` for the CI artifact; exits
non-zero (via ``run()`` raising) when the tuner misses a must-win shape
in any section.
"""
from __future__ import annotations

import json
import pathlib
import warnings

from benchmarks.common import emit
from repro.core import collectives as C
from repro.core import costmodel as cm
from repro.core import selfcheck, tuner
from repro.core.cell import OpCell
from repro.core.trace import Trace, TraceEntry

OPS = ("allgather_matmul", "matmul_reducescatter", "matmul_accumulate")
AXIS_SIZES = (4, 8, 16, 64)
SIZES = (64, 1024, 32768, 262_144, 1_048_576, 4_194_304, 16_777_216)
MIN_WIN = 0.10
WIRE_IMPLS = ("wire_q8", "wire_fp8")
#: fused-overlap selections the 1-D must-win gate accepts: the wire impls
#: run the same (p-1)-step overlap schedule with a compressed wire
RING_FAMILY = ("fused_ring",) + WIRE_IMPLS
#: comm-bound quantized cells must model at least this speedup over the
#: same cell's f32-wire fused impl — and then the tuner must pick them
WIRE_MUST_WIN = 1.5
#: 2-D section: (data, model) meshes x per-callsite GEMMs (T, K, M) — the
#: row_matmul(fsdp_dim=1) w_out shapes of serving-sized LMs, plus slivers
#: that must keep the default
MESHES_2D = ((2, 2), (4, 4), (8, 8), (16, 8))
GEMMS_2D = ((8192, 4096, 14336),      # mlp w_out, prefill batch
            (1024, 4096, 4096),       # attention w_o
            (256, 14336, 4096),       # mlp w_out, small decode batch
            (8, 512, 256))            # sliver: overhead must win
OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / \
    "BENCH_collective_matmul.json"
TRACE_OUT = OUT.with_name("BENCH_collective_matmul_cells.jsonl")


def sweep_cells(topo=cm.V5E_ICI):
    cells = []
    for op in OPS:
        for p in AXIS_SIZES:
            rep = tuner.tune(ops=[op], sizes=SIZES, axis_size=p,
                             backend=tuner.CostModelBackend(topo),
                             min_win=MIN_WIN)
            for nbytes in SIZES:
                t_def = cm.latency(op, "default", p, nbytes, topo)
                t_fus = cm.latency(op, "fused_ring", p, nbytes, topo)
                pick = rep.profiles.lookup(op, p, nbytes) or "default"
                cells.append({"op": op, "p": p, "nbytes": nbytes,
                              "t_default_s": t_def, "t_fused_s": t_fus,
                              "t_wire_q8_s": cm.latency(op, "wire_q8", p,
                                                        nbytes, topo),
                              "t_wire_fp8_s": cm.latency(op, "wire_fp8", p,
                                                         nbytes, topo),
                              "model_win": t_def / t_fus,
                              "tuner_pick": pick,
                              "wire_dtype": C.REGISTRY[op][pick].wire_dtype})
    return cells


def sweep_cells_wire(topo=cm.V5E_DCN):
    """The fused grid re-priced where the wire bytes dominate (DCN tier):
    per cell, the f32-wire fused ring vs both 8-bit wire impls, plus the
    tuner's pick on the same topo."""
    rows = []
    for op in OPS:
        for p in AXIS_SIZES:
            rep = tuner.tune(ops=[op], sizes=SIZES, axis_size=p,
                             backend=tuner.CostModelBackend(topo),
                             min_win=MIN_WIN)
            for nbytes in SIZES:
                t_fus = cm.latency(op, "fused_ring", p, nbytes, topo)
                t_wire = {nm: cm.latency(op, nm, p, nbytes, topo)
                          for nm in WIRE_IMPLS}
                pick = rep.profiles.lookup(op, p, nbytes) or "default"
                rows.append({"op": op, "p": p, "nbytes": nbytes,
                             "t_default_s": cm.latency(op, "default", p,
                                                       nbytes, topo),
                             "t_fused_s": t_fus,
                             "t_wire_q8_s": t_wire["wire_q8"],
                             "t_wire_fp8_s": t_wire["wire_fp8"],
                             "wire_win": t_fus / min(t_wire.values()),
                             "tuner_pick": pick,
                             "wire_dtype": C.REGISTRY[op][pick].wire_dtype})
    return rows


def _gate_payload(op: str, p: int):
    """A small representative payload for ``selfcheck.run_gate`` — the
    shapes mirror selfcheck's SPMD suite, scaled down per p."""
    import numpy as np
    rng = np.random.default_rng(11)
    if op == "allgather_matmul":
        return (rng.normal(size=(p, 4, 16)).astype(np.float32),
                rng.normal(size=(16, 8)).astype(np.float32))
    if op == "matmul_reducescatter":
        # per-rank rows must divide by p for the scatter
        return (rng.normal(size=(p, 2 * p, 16)).astype(np.float32),
                rng.normal(size=(16, 8)).astype(np.float32))
    if op == "matmul_accumulate":
        # x = stacked weight K-blocks [p, k_loc, m]; w = stationary [T, K]
        return (rng.normal(size=(p, 2, 8)).astype(np.float32),
                rng.normal(size=(4, 2 * p)).astype(np.float32))
    raise KeyError(op)


def gate_selected_wire(cells_wire):
    """Run the selfcheck tolerance gate on every DISTINCT wire selection
    of the DCN sweep; any break demotes (and fails the bench)."""
    gates = []
    seen = set()
    for c in cells_wire:
        key = (c["op"], c["tuner_pick"], c["p"])
        if c["tuner_pick"] not in WIRE_IMPLS or key in seen:
            continue
        seen.add(key)
        x, w = _gate_payload(c["op"], c["p"])
        ok, rel, tol = selfcheck.run_gate(c["op"], c["tuner_pick"], x, w=w)
        gates.append({"op": c["op"], "impl": c["tuner_pick"], "p": c["p"],
                      "rel_err": rel, "tol": tol, "ok": ok})
    return gates


def _trace_artifact_check(cells_wire):
    """Write the swept cells as a schema-v2 trace artifact (with a non-f32
    geometry cell in the mix) and reload it with DeprecationWarning
    promoted to an error — newly-produced artifacts must never trip the
    v1-sunset path."""
    entries = [TraceEntry.of(c["op"], c["p"], c["nbytes"], "fwd",
                             c["tuner_pick"], 1)
               for c in cells_wire]
    entries.append(TraceEntry.of("allgather_matmul", 8, 262_144, "fwd",
                                 "wire_q8", 1, dtype="bfloat16",
                                 mm_k=512, mm_m=2048, mm_n=64,
                                 mm_role="gather"))
    t = Trace(entries)
    t.save(TRACE_OUT)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        back = Trace.load(TRACE_OUT)
    if back != t:
        raise AssertionError(f"{TRACE_OUT.name} did not round-trip")
    bf16 = [cell for cell in back.cells() if cell.dtype == "bfloat16"]
    if not bf16:
        raise AssertionError("non-f32 dtype lost in the trace artifact")


def _cell_2d(d: int, q: int, t: int, k: int, m: int) -> OpCell:
    """The dispatch cell row_matmul(fsdp_dim=1) records on a (d, q) mesh
    for the logical GEMM [t, k] @ [k, m]: per-rank dims, payload = the
    streamed weight column block [k/q, m/d]."""
    k_loc, m_loc = max(1, k // q), max(1, m // d)
    return OpCell("matmul_reducescatter_2d", d, k_loc * m_loc * 4,
                  "float32", mm_k=k_loc, mm_m=t, mm_n=d * m_loc,
                  mm_role="2d", p2=q)


def sweep_cells_2d(topo=cm.V5E_ICI):
    """Three-way modeled comparison per 2-D cell: unfused vs the 1-D
    status quo (fsdp_matmul fused + monolithic model-axis allreduce) vs
    the nested 2-D schedule, plus the trace-tuner's per-cell pick."""
    rows = []
    entries = []
    for d, q in MESHES_2D:
        for t, k, m in GEMMS_2D:
            cell = _cell_2d(d, q, t, k, m)
            entries.append(TraceEntry(cell, "fwd", "default", 1))
    rep = tuner.tune_trace(Trace(entries),
                           backend=tuner.CostModelBackend(topo),
                           min_win=MIN_WIN)
    store = rep.store("fwd")
    for d, q in MESHES_2D:
        for t, k, m in GEMMS_2D:
            cell = _cell_2d(d, q, t, k, m)
            t_unf = cm.latency_cell(cell, "default", topo)
            t_2d = cm.latency_cell(cell, "fused_ring2d", topo)
            # 1-D status quo: the data-axis weight gather fused
            # (allgather_matmul with the weight as the gathered operand,
            # fsdp_matmul's formulation) + an unfused model allreduce of
            # the [t, m] partial products
            agmm = OpCell("allgather_matmul", d, cell.nbytes, "float32",
                          mm_k=cell.mm_k, mm_m=cell.mm_n, mm_n=t,
                          mm_role="gather")
            t_1d = (cm.latency_cell(agmm, "fused_ring", topo)
                    + cm.latency("allreduce", "default", q, t * m * 4,
                                 topo))
            # every cell here was IN the trace, so the tuner's per-cell
            # verdict is its EXACT geometry profile (the nearest-geometry
            # fallback is for unseen shapes and would leak big-cell wins
            # onto slivers)
            prof = store.get("matmul_reducescatter_2d", d,
                             cell.geom()) if store else None
            pick = (prof.lookup(cell.nbytes) if prof else None) or "default"
            best = min(("default", t_unf), ("fused_1d", t_1d),
                       ("fused_ring2d", t_2d), key=lambda kv: kv[1])[0]
            rows.append({"d": d, "q": q, "gemm": [t, k, m],
                         "nbytes": cell.nbytes,
                         "t_unfused_s": t_unf, "t_fused1d_s": t_1d,
                         "t_fused2d_s": t_2d,
                         "model_win_vs_unfused": t_unf / t_2d,
                         "model_win_vs_1d": t_1d / t_2d,
                         "modeled_best": best, "tuner_pick": pick})
    return rows


def run():
    cells = sweep_cells()
    must_win = [c for c in cells if c["t_fused_s"]
                < c["t_default_s"] * (1.0 - MIN_WIN)]
    missed = [c for c in must_win if c["tuner_pick"] not in RING_FAMILY]
    n_fused = sum(1 for c in cells if c["tuner_pick"] in RING_FAMILY)
    n_default_small = sum(1 for c in cells
                          if c["nbytes"] <= 1024
                          and c["tuner_pick"] == "default")
    cells_wire = sweep_cells_wire()
    wire_must = [c for c in cells_wire if c["wire_win"] >= WIRE_MUST_WIN]
    missed_wire = [c for c in wire_must
                   if c["tuner_pick"] not in WIRE_IMPLS]
    wire_slivers = [c for c in cells_wire
                    if c["nbytes"] <= 1024 and c["tuner_pick"] != "default"]
    wire_gates = gate_selected_wire(cells_wire)
    cells_2d = sweep_cells_2d()
    must_win_2d = [c for c in cells_2d
                   if c["t_fused2d_s"] < min(c["t_unfused_s"],
                                             c["t_fused1d_s"])
                   * (1.0 - MIN_WIN)]
    missed_2d = [c for c in must_win_2d
                 if c["tuner_pick"] != "fused_ring2d"]
    n_default_2d = sum(1 for c in cells_2d if c["tuner_pick"] == "default")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({
        "min_win": MIN_WIN, "cells": cells,
        "must_win_cells": len(must_win), "missed": missed,
        "cells_2d": cells_2d, "must_win_cells_2d": len(must_win_2d),
        "missed_2d": missed_2d,
        "wire_must_win": WIRE_MUST_WIN, "cells_wire": cells_wire,
        "wire_must_win_cells": len(wire_must),
        "missed_wire": missed_wire, "wire_gates": wire_gates,
    }, indent=1))
    _trace_artifact_check(cells_wire)
    for op in OPS:
        best = max((c["model_win"] for c in cells if c["op"] == op),
                   default=0.0)
        emit(f"collective_matmul/{op}", 0.0,
             f"fused_selected={sum(1 for c in cells if c['op'] == op and c['tuner_pick'] == 'fused_ring')}"
             f"/{sum(1 for c in cells if c['op'] == op)}"
             f" best_model_win=x{best:.2f}")
    n2 = len(cells_2d)
    n2_fused = sum(1 for c in cells_2d if c["tuner_pick"] == "fused_ring2d")
    best_2d = max((c["model_win_vs_unfused"] for c in cells_2d),
                  default=0.0)
    emit("collective_matmul/matmul_reducescatter_2d", 0.0,
         f"fused_selected={n2_fused}/{n2} best_model_win=x{best_2d:.2f} "
         f"must_win_vs_both={len(must_win_2d)}")
    if missed:
        raise AssertionError(
            f"tuner missed {len(missed)} must-win fused cells, e.g. "
            f"{missed[0]}")
    if not must_win or n_fused == 0:
        raise AssertionError("overlap model never favors fused_ring — "
                             "cost model regression")
    if n_default_small == 0:
        raise AssertionError("fused_ring selected even on tiny messages — "
                             "per-step overhead lost from the model")
    if missed_2d:
        raise AssertionError(
            f"tuner missed {len(missed_2d)} must-win 2-D fused cells "
            f"(vs BOTH the unfused and 1-D compositions), e.g. "
            f"{missed_2d[0]}")
    if not must_win_2d:
        raise AssertionError("nested-overlap model never beats both the "
                             "unfused and 1-D compositions — 2-D cost "
                             "model regression")
    if n_default_2d == 0:
        raise AssertionError("fused_ring2d selected even on sliver GEMMs — "
                             "the per-step overhead on both axes is lost "
                             "from the model")
    n_wire = sum(1 for c in cells_wire if c["tuner_pick"] in WIRE_IMPLS)
    best_wire = max((c["wire_win"] for c in cells_wire), default=0.0)
    emit("collective_matmul/wire",
         0.0,
         f"wire_selected={n_wire}/{len(cells_wire)} "
         f"best_wire_win=x{best_wire:.2f} must_win={len(wire_must)} "
         f"gated={len(wire_gates)}")
    if missed_wire:
        raise AssertionError(
            f"tuner missed {len(missed_wire)} comm-bound quantized cells "
            f"(wire models >= {WIRE_MUST_WIN}x over fused_ring), e.g. "
            f"{missed_wire[0]}")
    if not wire_must:
        raise AssertionError(
            f"no DCN cell models a >= {WIRE_MUST_WIN}x quantized-wire win "
            f"over fused_ring — wire cost model regression")
    if wire_slivers:
        raise AssertionError(
            f"{len(wire_slivers)} sliver cells (<= 1KiB) did not keep the "
            f"default on the DCN sweep, e.g. {wire_slivers[0]}")
    bad_gates = [g for g in wire_gates if not g["ok"]]
    if bad_gates or C.demotions():
        raise AssertionError(
            f"selected wire impls broke the selfcheck tolerance gate: "
            f"{bad_gates or C.demotions()}")
    if not wire_gates:
        raise AssertionError("no wire selection was tolerance-gated — "
                             "selection plumbing regression")
    emit("collective_matmul/consistency", 0.0,
         f"must_win={len(must_win)} missed=0 must_win_2d={len(must_win_2d)} "
         f"missed_2d=0 wire_must_win={len(wire_must)} missed_wire=0 "
         f"json={OUT.name}")


def main():
    run()
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
