"""Hierarchical per-axis topology: fitted tiers, (2,2,2) trace, must-wins.

The flat-link cost model priced every mesh axis with one ``Topo`` even
when a cell's ring crossed the ~4x ICI/DCN bandwidth gap.  This benchmark
exercises the per-axis replacement end to end and gates the wins:

1. FIT — a subprocess forces 8 host devices and runs
   ``measure.sweep_axis`` allgather + allreduce sweeps; ``fit_topo``
   turns them into the reachable tier's alpha/beta/gamma.  The DCN tier
   is DERIVED from those fitted absolutes with the published relative
   gaps (``Topo.scaled`` x ``DCN_ALPHA_MULT``/``DCN_BW_MULT``) — no
   hard-coded constants enter the mesh the tuner prices with.
2. TRACE — a (pod, data, model) = (2, 2, 2) shard_map harness runs
   DCN-crossing hierarchical collectives (``inner_axis=`` dispatch) and
   a flat intra-pod sync under ``api.tuned(record=..., mesh_topo=...)``;
   the recorded cells carry ``p2`` and the tier token.
3. TUNE + MUST-WIN — ``tune_trace`` over the fitted ``MeshTopo`` must
   select the hierarchical mock-ups on the DCN-crossing cells; the
   modeled allreduce win over the flat joint ring (the cell the ISSUE
   names) must clear ``RATIO_GATE``; the flat sibling must never pick a
   hierarchical impl.
4. LAYOUT — the mesh-layout question: re-key the traced grad-sync cell
   to the candidate layouts of the same world (flat ring on DCN,
   DCN-outer hierarchy, DCN-inner hierarchy) and compare each layout's
   best LOSSLESS schedule; the DCN-outer hierarchy must win outright.

Payload size is chosen FROM THE FIT (smallest power of two making the
modeled bandwidth term dominate the DCN alpha term), so the must-win
cells are comm-bound by construction on whatever this host measures.

  PYTHONPATH=src python benchmarks/bench_hierarchy.py --smoke
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

from benchmarks.common import emit, header
from repro.core import costmodel as cm, tuner
from repro.core.cell import OpCell
from repro.core.collectives import REGISTRY
from repro.core.trace import Trace, TraceEntry

RATIO_GATE = 1.1        # modeled default/hier floor on the must-win cell
HIER_IMPL = {"allreduce": "MPIX_rs_ar_ag", "allgather": "MPIX_ag_ag",
             "reducescatter": "MPIX_rs_rs"}

FIT_SCRIPT = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.core import measure
sizes = [int(s) for s in sys.argv[1].split(",")]
count = int(sys.argv[2])
print(json.dumps({
    "p": measure.axis_size(),
    "allgather": measure.sweep_axis("allgather", sizes, count=count),
    "allreduce": measure.sweep_axis("allreduce", sizes, count=count),
}))
"""

TRACE_SCRIPT = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro._compat import shard_map
from repro.core import api, costmodel as cm
from repro.core.trace import Trace, TraceEntry
from repro.launch.mesh import make_host_mesh

spec = json.loads(sys.argv[1])
mk = lambda d: cm.Topo(d["name"], alpha=d["alpha"], link_bw=d["link_bw"],
                       gamma=d["gamma"])
ici, dcn = mk(spec["ici"]), mk(spec["dcn"])
mt = cm.MeshTopo.of(pod=dcn, data=ici, model=ici)
mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))

n = max(spec["nbytes"] // 4, 8)          # float32 elements per rank
r_rows = 4 * max(n // 8, 1)              # reducescatter: divisible by world

def body(xa, xr, xs):
    # DCN-crossing hierarchical group: pod (inter) outer, data (intra) in
    g = api.allreduce(xa[0], "pod", inner_axis="data")
    h = api.allgather(xa[0], "pod", inner_axis="data")
    r = api.reducescatter(xr[0], "pod", inner_axis="data")
    # flat intra-pod sync: the sibling that must NOT pick a hier mock-up
    s = api.allreduce(xs[0], "model")
    # all-ones input: allreduce/reducescatter sum 4 ranks, gather keeps 1,
    # model allreduce sums 2 — max deviation is the semantic check
    return (jnp.abs(g - 4.0).max() + jnp.abs(h - 1.0).max()
            + jnp.abs(r - 4.0).max() + jnp.abs(s - 2.0).max())[None]

sp = NamedSharding(mesh, P(("pod", "data", "model")))
xa = jax.device_put(jnp.ones((8, n), jnp.float32), sp)
xr = jax.device_put(jnp.ones((8, r_rows, 2), jnp.float32), sp)
xs = jax.device_put(jnp.ones((8, 64), jnp.float32), sp)

recs = []
with api.tuned(record=recs, mesh_topo=mt):
    sm = shard_map(body, mesh=mesh,
                   in_specs=(P(("pod", "data", "model")),) * 3,
                   out_specs=P(("pod", "data", "model")), check_vma=False)
    dev = jax.block_until_ready(jax.jit(sm)(xa, xr, xs))
t = Trace([TraceEntry(r.cell, r.phase, r.impl) for r in recs])
print(json.dumps({"trace": t.to_jsonl(),
                  "ok": bool(jnp.max(dev) < 1e-5)}))
"""


def _run_child(code, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code, *args],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _topo_dict(t: cm.Topo) -> dict:
    return {"name": t.name, "alpha": t.alpha, "link_bw": t.link_bw,
            "gamma": t.gamma}


def fit_tiers(sizes, count, failures):
    """Fitted base tier + ratio-derived DCN tier (step 1)."""
    fit = _run_child(FIT_SCRIPT, ",".join(str(s) for s in sizes),
                     str(count))
    p = fit["p"]
    ici = cm.fit_topo(p, fit["allgather"], fit["allreduce"],
                      name="host-ici")
    dcn = ici.scaled(name="host-dcn", alpha_mult=cm.DCN_ALPHA_MULT,
                     bw_mult=cm.DCN_BW_MULT)
    emit("hierarchy/fit/axis_size", float(p))
    emit("hierarchy/fit/alpha_us", ici.alpha * 1e6, "host-ici")
    emit("hierarchy/fit/bw_gbps", ici.link_bw / 1e9, "host-ici")
    emit("hierarchy/fit/gamma_ps_per_byte", ici.gamma * 1e12, "host-ici")
    for v, what in ((ici.alpha, "alpha"), (ici.beta, "beta"),
                    (ici.gamma, "gamma")):
        if not (math.isfinite(v) and v >= 0.0):
            failures.append(f"fitted {what} = {v} is not a usable "
                            "fabric parameter")
    if dcn.alpha != ici.alpha * cm.DCN_ALPHA_MULT or \
            dcn.link_bw != ici.link_bw * cm.DCN_BW_MULT:
        failures.append("derived DCN tier does not anchor to the fitted "
                        "absolutes via the published ratios")
    return ici, dcn, fit


def comm_bound_bytes(ici, dcn, cap):
    """Smallest power-of-two payload whose modeled bandwidth term
    dominates the DCN message latency on the must-win cell."""
    b = 1 << 20
    while b < cap and b * ici.beta < 10.0 * dcn.alpha:
        b *= 2
    return min(b, cap)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/BENCH_hierarchy.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (short sweeps, small payload cap)")
    args = ap.parse_args(argv)

    header()
    failures: list[str] = []
    if args.smoke:
        sizes, count, cap = (4096, 65536, 524288), 3, 4 << 20
    else:
        sizes, count, cap = (4096, 16384, 65536, 262144, 1 << 20,
                             4 << 20), 5, 32 << 20

    # -- 1. fit the reachable tier; derive DCN from the fitted absolutes ----
    ici, dcn, fit = fit_tiers(sizes, count, failures)
    mesh = cm.MeshTopo.of(pod=dcn, data=ici, model=ici)
    nbytes = comm_bound_bytes(ici, dcn, cap)
    emit("hierarchy/payload_bytes", float(nbytes),
         "comm-bound by construction" if nbytes < cap else "capped")

    # -- 2. trace the (2,2,2) harness through api dispatch ------------------
    tr = _run_child(TRACE_SCRIPT, json.dumps({
        "ici": _topo_dict(ici), "dcn": _topo_dict(dcn), "nbytes": nbytes}))
    if not tr["ok"]:
        failures.append("(2,2,2) harness collectives returned wrong values")
    trace = Trace.from_jsonl(tr["trace"])
    hier_cells = {c for c in trace.cells() if c.hier}
    flat_cells = {c for c in trace.cells() if not c.hier}
    emit("hierarchy/trace/cells", float(len(trace)),
         f"{len(hier_cells)} hier / {len(flat_cells)} flat")
    if {c.op for c in hier_cells} != set(HIER_IMPL):
        failures.append(f"harness recorded hier ops "
                        f"{sorted(c.op for c in hier_cells)}, expected "
                        f"{sorted(HIER_IMPL)}")
    for c in hier_cells:
        if c.tier != "host-dcn/host-ici" or c.p2 == 0:
            failures.append(f"hier cell {c.op} lost its tier stamp: "
                            f"tier={c.tier!r} p2={c.p2}")

    # -- 3. tune + must-win gates -------------------------------------------
    backend = tuner.CostModelBackend(mesh)
    rep = tuner.tune_trace(trace, backend=backend, min_win=0.05)
    store = next(iter(rep.phase_profiles.values())) \
        if rep.phase_profiles else None
    selections = {}
    for c in sorted(hier_cells, key=lambda c: c.op):
        sel = store.lookup_cell(c) if store is not None else None
        selections[c.op] = sel
        t_def = backend.latency(c, "default")
        t_sel = backend.latency(c, sel) if sel else t_def
        ratio = t_def / t_sel if t_sel else 0.0
        emit(f"hierarchy/select/{c.op}", t_sel * 1e6,
             f"{sel or 'default'} {ratio:.2f}x vs flat ring")
        if sel != HIER_IMPL[c.op]:
            failures.append(
                f"must-win missed: {c.op} cell (p={c.p}, q={c.p2}, "
                f"{c.nbytes}B, {c.tier}) selected {sel!r}, expected "
                f"{HIER_IMPL[c.op]}")
        elif c.op == "allreduce" and ratio < RATIO_GATE:
            failures.append(
                f"hierarchical allreduce win {ratio:.3f}x below the "
                f"{RATIO_GATE}x gate on the DCN-crossing cell")
    for c in flat_cells:
        sel = store.lookup_cell(c) if store is not None else None
        if sel in HIER_IMPL.values():
            failures.append(f"flat cell {c.op}@p{c.p} selected the "
                            f"hierarchical mock-up {sel}")

    # -- 4. the mesh-layout question ----------------------------------------
    # same world, same payload, three ways to lay the sync group out
    # across the DCN boundary; the tuner must put DCN on the OUTER axis
    # of the hierarchy (1/q of the bytes cross it there).
    ar = next(c for c in hier_cells if c.op == "allreduce")
    w = ar.world()
    layouts = {
        "flat-dcn": OpCell("allreduce", w, ar.nbytes, tier=dcn.name),
        "dcn-outer": ar,
        "dcn-inner": OpCell("allreduce", ar.p, ar.nbytes, p2=ar.p2,
                            tier=f"{ici.name}/{dcn.name}"),
    }
    # each layout gets its best LOSSLESS schedule — the wire-quantized
    # family trades precision for bytes, which answers a different
    # question than where to put the DCN boundary
    costs = {}
    n_calls = trace.cells()[ar]
    for name, cell in layouts.items():
        impl, t = min(
            ((nm, t) for nm, t in cm.sweep_cell(cell, mesh).items()
             if math.isfinite(t)
             and REGISTRY[cell.op][nm].wire_dtype is None),
            key=lambda kv: kv[1])
        costs[name] = n_calls * t
        emit(f"hierarchy/layout/{name}_us", costs[name] * 1e6, impl)
    best = min(costs, key=costs.get)
    emit("hierarchy/layout/winner", float(best == "dcn-outer"), best)
    if best != "dcn-outer":
        failures.append(f"mesh-layout question answered {best!r}; the "
                        "DCN-outer hierarchy must minimize the workload")
    if not costs["dcn-outer"] < costs["dcn-inner"] < costs["flat-dcn"]:
        failures.append(f"layout ordering violated: {costs} — expected "
                        "dcn-outer < dcn-inner < flat-dcn")

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "fit": {"axis_size": fit["p"], "sizes": list(sizes),
                "count": count, "ici": _topo_dict(ici),
                "dcn": _topo_dict(dcn)},
        "payload_bytes": nbytes,
        "trace_cells": len(trace),
        "selections": selections,
        "layout_costs_us": {k: v * 1e6 for k, v in costs.items()},
        "layout_winner": best,
        "failures": failures,
    }, indent=1))

    for f in failures:
        print(f"ERROR: {f}", file=sys.stderr)
    return 1 if failures else 0


def run():
    # benchmarks/run.py entry point: smoke-sized so the suite stays fast
    rc = main(["--smoke"])
    if rc:
        raise RuntimeError("bench_hierarchy smoke failed")


if __name__ == "__main__":
    raise SystemExit(main())
