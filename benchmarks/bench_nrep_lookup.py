"""Paper §3.2.3 + §4.2 microbenchmarks:

1. NREP estimation (Alg. 1 / Eq. 1) against a real wall-clock sampler.
2. Profile lookup latency — the O(1) hash + O(log M) bisect claim.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import measure, nrep
from repro.core.profiles import Profile, ProfileStore, Range


def run():
    # --- NREP on a real sampler (host-device collective wall clock) --------
    sampler = measure.make_sampler(measure.host_cell("allreduce", 1),
                                   "default")
    t0 = time.perf_counter()
    ob = nrep.estimate_1byte(sampler, rse_threshold=0.05, batch0=5,
                             max_samples=60)
    emit("nrep/1byte_estimation", (time.perf_counter() - t0) * 1e6,
         f"nrep={ob.nrep} rse={ob.final_rse:.4f}")
    for msize in (1024, 65_536, 1_048_576):
        n = nrep.estimate_nrep(sampler, msize, ob, K=5)
        emit(f"nrep/eq1_nrep/{msize}B", 0.0, f"nrep={n}")

    # --- profile lookup scaling --------------------------------------------
    for m in (8, 64, 512, 4096):
        ranges = [Range(i * 10, i * 10 + 9, f"alg{i % 5}") for i in range(m)]
        prof = Profile(op="allreduce", axis_size=256, ranges=ranges)
        store = ProfileStore([prof])
        qs = np.random.default_rng(0).integers(0, m * 10, 10_000)
        t0 = time.perf_counter()
        for q in qs:
            store.lookup("allreduce", 256, int(q))
        dt = (time.perf_counter() - t0) / len(qs)
        emit(f"lookup/M={m}", dt * 1e6, "O(log M) bisect")


if __name__ == "__main__":
    run()
