"""Dispatch hot-path micro-benchmark (the ``api._select`` fast path).

Dispatch runs at trace time, so the cost that matters is Python overhead
per collective call while jit-tracing a model.  Trace a chain of ``N``
``api.allreduce`` calls under three regimes and report µs per dispatch:

* ``no_ctx``        — bare (no ``api.tuned`` active): fast path, no record
* ``tuned_empty``   — ``api.tuned()`` with no force/profiles: fast path
                      with recording (the common training configuration)
* ``tuned_profiles``— a populated ``ProfileStore``: full lookup machinery

The fast path must keep ``tuned_empty`` within ~2x of ``no_ctx``.  Since
the shape-aware cell refactor, recording builds a full ``OpCell`` per
dispatch (geometry capture), so ``tuned_empty`` and ``tuned_profiles``
sit close together — the short-circuit's win is skipping the
phase/profile lookup machinery, not the record itself.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import api
from repro.core.profiles import Profile, ProfileStore, Range

N = 200          # dispatches per trace
REPS = 5


def _chain(x):
    for _ in range(N):
        x = api.allreduce(x, "x")
    return x


def _trace_time():
    f = jax.vmap(_chain, axis_name="x")
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.make_jaxpr(f)(jnp.ones((4, 8), jnp.float32))
        best = min(best, time.perf_counter() - t0)
    return best / N * 1e6          # us per dispatch


def run():
    base = _trace_time()
    emit("dispatch/no_ctx", base, "fast path, no record")

    with api.tuned():
        fast = _trace_time()
    emit("dispatch/tuned_empty", fast,
         f"fast path + record; overhead x{fast / max(base, 1e-9):.2f} "
         f"vs no_ctx")

    store = ProfileStore([Profile(op="allreduce", axis_size=4,
                                  ranges=[Range(1, 10 ** 9,
                                                "allreduce_as_doubling")])])
    with api.tuned(profiles=store):
        slow = _trace_time()
    emit("dispatch/tuned_profiles", slow,
         f"full lookup; fast-path speedup x{slow / max(fast, 1e-9):.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
