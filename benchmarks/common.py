"""Shared helpers for the benchmark suite (CSV row emission)."""
from __future__ import annotations

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.4f},{derived}")


def header():
    print("name,us_per_call,derived")
