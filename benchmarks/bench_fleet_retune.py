"""Fleet-scale online retuning: shards → merged profile → live hot-swap.

The continuous-retuning loop at serving scale, simulated on one host:

1. FLEET RECORDING — four "servers" serve a smoke LM over emulated tensor
   parallelism (``vmap(axis_name="model")``, the CPU stand-in for a TP
   mesh), each with a different traffic mix (batch / prompt length), each
   recording into a bounded ``trace.ShardRecorder`` and flushing an
   epoch-stamped shard file.
2. MERGE + TUNE — ``Trace.merge_shards`` folds the shard directory into
   one fleet trace (count summation, weight preserved);
   ``tuner.tune_trace`` emits per-phase profiles from the union workload.
   Gate: on the union workload, the merged-trace profile's modeled cost
   is <= every single-shard profile's (a shard only sees its own slice,
   so its profile leaves the other servers' cells untuned).
3. HOT SWAP — a serve loop built ONCE with ``api.tuned(store_ref=...,
   plan=...)`` keeps stepping while the tuned generation is published to
   ``$PGTUNE_PROFILE_DIR`` (profiles first, ``MANIFEST.json`` last).
   ``StoreRef.poll`` adopts the new epoch, ``Plan.vector(ref)`` re-derives
   the runtime dispatch vector, and the next steps serve the tuned impls.
   Gate: ZERO new jit compilations across the swap (``_cache_size()``
   instrumented) while the plan vector provably changed.
4. STALENESS — a delayed writer regressing the manifest to an older epoch
   is refused (warning, live generation keeps serving).
5. EXPLORATION — an epsilon slice of steps runs ``Plan.explore`` vectors
   (runner-up impls), latencies are fed back via
   ``ShardRecorder.observe`` → ``#@lat`` shard lines →
   ``tuner.FeedbackBackend``, and the next epoch is tuned from the
   fleet's own measurements and hot-swapped in the same way.

Wall-clock on this CPU container measures emulation overhead; decision
quality is the cost-model latency (same convention as the other
benchmarks).  Exploration "measurements" are therefore cost-model samples
with noise — the plumbing (shards, reservoirs, feedback override) is what
this benchmark exercises end to end.

  PYTHONPATH=src python benchmarks/bench_fleet_retune.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import warnings

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core import api, costmodel as cm, tuner
from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
from repro.core.trace import (ShardRecorder, Trace, load_shard_latencies,
                              shard_digest)
from repro.models import lm
from repro.models.params import init_tree


def make_params(cfg, tp):
    specs = lm.model_specs(cfg, tp=tp)

    def init(key):
        return init_tree(specs, key, fold=lax.axis_index("model"))

    return jax.jit(jax.vmap(init, axis_name="model", axis_size=tp,
                            in_axes=None, out_axes=0))(jax.random.key(0))


def make_steps(cfg, tp, s_max, batch):
    """Prefill/decode jits with a TRAILING replicated plan-vector arg —
    the vector must be an argument (not a closure) so new epochs are new
    VALUES to an already-compiled step, never new constants."""

    def init_c(_):
        return lm.init_caches(cfg, batch, s_max)

    def pf(p, c, prompts, vec):
        with api.plan_input(vec):
            return lm.prefill(p, cfg, {"tokens": prompts}, c)

    def dc(p, t, c, i, vec):
        with api.plan_input(vec):
            return lm.decode_step(p, cfg, t, c, i)

    j_init = jax.jit(jax.vmap(init_c, axis_name="model", axis_size=tp,
                              in_axes=None, out_axes=0))
    j_pf = jax.jit(jax.vmap(pf, axis_name="model",
                            in_axes=(0, 0, None, None)))
    j_dc = jax.jit(jax.vmap(dc, axis_name="model",
                            in_axes=(0, None, 0, None, None)))
    return j_init, j_pf, j_dc


def serve_pass(cfg, steps, params, prompts, n_tokens, vec):
    """One prefill + greedy decode pass, phase-tagged like launch/serve."""
    j_init, j_pf, j_dc = steps
    caches = j_init(0)
    with api.phase("prefill"):
        logits, caches = j_pf(params, caches, prompts, vec)
    tok = (jnp.argmax(logits[0][:, -1], axis=-1).astype(jnp.int32)[:, None]
           % cfg.vocab_size)
    out = [tok]
    with api.phase("decode"):
        for step in range(n_tokens - 1):
            lg, caches = j_dc(params, tok, caches,
                              jnp.int32(prompts.shape[1] + step), vec)
            tok = (jnp.argmax(lg[0][:, -1], axis=-1).astype(jnp.int32)
                   [:, None] % cfg.vocab_size)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def cache_sizes(steps):
    return tuple(s._cache_size() for s in steps)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--tp", type=int, default=2,
                    help="emulated model-axis size")
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--topo", default="bgq-like", choices=sorted(cm.PRESETS))
    ap.add_argument("--min-win", type=float, default=0.10)
    ap.add_argument("--eps", type=float, default=0.5,
                    help="exploration budget (fraction of plan sites)")
    ap.add_argument("--out", default="results/fleet_retune")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tiny fleet / token budget)")
    args = ap.parse_args(argv)

    if args.smoke:
        # eps=1 flips every multi-impl site — the exploration gate must be
        # deterministic in CI, not a coin flip over a handful of sites
        args.tokens, args.eps = 4, 1.0

    topo = cm.PRESETS[args.topo]
    cfg = get_config(args.arch).smoke()
    # four servers, four traffic mixes: (batch, prompt_len)
    fleet = [(1, 8), (2, 16), (1, 32), (2, 8)]
    s_max = max(pl for _, pl in fleet) + args.tokens + 8
    backend = tuner.CostModelBackend(topo)

    header()
    out = pathlib.Path(args.out)
    shard_dir = out / "shards"
    live_dir = out / "live_profiles"
    import shutil
    for d in (shard_dir, live_dir):
        shutil.rmtree(d, ignore_errors=True)
    for d in (out, shard_dir, live_dir):
        d.mkdir(parents=True, exist_ok=True)
    failures: list[str] = []

    # -- 1. fleet recording: one bounded shard per server --------------------
    rng = np.random.default_rng(0)
    for i, (batch, plen) in enumerate(fleet):
        params = make_params(cfg, args.tp)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
        rec = ShardRecorder(f"srv{i}", seed=i)
        steps = make_steps(cfg, args.tp, s_max, batch)
        with api.tuned(record=rec):
            serve_pass(cfg, steps, params, prompts, args.tokens,
                       jnp.zeros(1, jnp.int32))
        path = rec.flush(shard_dir, epoch=1)
        emit(f"fleet_retune/shard{i}/dispatches",
             float(Trace.load(path).total()), path.name)

    # -- 2. merge + tune: fleet profile must cover every server's slice ------
    fleet_trace = Trace.merge_shards(shard_dir)
    shard_traces = [Trace.load(p)
                    for p in sorted(shard_dir.glob("shard-*.jsonl"))]
    assert fleet_trace.total() == sum(t.total() for t in shard_traces)
    emit("fleet_retune/merged/cells", float(len(fleet_trace)))
    emit("fleet_retune/merged/dispatches", float(fleet_trace.total()))

    rep = tuner.tune_trace(fleet_trace, backend=backend,
                           min_win=args.min_win)
    cost_merged = sum(tuner.estimate_trace_cost(
        fleet_trace, backend, phases=rep.phase_profiles).values())
    cost_default = sum(tuner.estimate_trace_cost(fleet_trace,
                                                 backend).values())
    emit("fleet_retune/union_cost_default_us", cost_default * 1e6)
    emit("fleet_retune/union_cost_merged_us", cost_merged * 1e6,
         f"{cost_default / cost_merged:.2f}x" if cost_merged else "")
    for i, t in enumerate(shard_traces):
        rep_i = tuner.tune_trace(t, backend=backend, min_win=args.min_win)
        cost_i = sum(tuner.estimate_trace_cost(
            fleet_trace, backend, phases=rep_i.phase_profiles).values())
        emit(f"fleet_retune/union_cost_shard{i}_us", cost_i * 1e6)
        if cost_merged > cost_i * (1 + 1e-9):
            failures.append(
                f"merged profile costs {cost_merged:.3e}s on the union "
                f"workload, worse than shard {i}'s profile ({cost_i:.3e}s)")

    # -- 3. live serve + hot swap (zero re-jits) -----------------------------
    os.environ[PROFILE_DIR_ENV] = str(live_dir)
    ref = resolve_stores(watch=True)
    if ref.epoch >= 0:
        failures.append(f"empty live dir resolved to epoch {ref.epoch}")
    plan = api.Plan(capacity=64)
    batch, plen = fleet[1]
    params = make_params(cfg, args.tp)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
    steps = make_steps(cfg, args.tp, s_max, batch)
    rec2 = ShardRecorder("live", seed=99)

    with api.tuned(store_ref=ref, plan=plan, record=rec2):
        vec0 = jnp.asarray(plan.vector(ref))
        gen0 = serve_pass(cfg, steps, params, prompts, args.tokens, vec0)
        gen0.block_until_ready()
        sizes0 = cache_sizes(steps)
        emit("fleet_retune/plan_sites", float(len(plan)),
             f"capacity {plan.capacity}")
        if len(plan) == 0:
            failures.append("no dispatch sites registered on the plan")

        # publish epoch 1 (profiles first, MANIFEST last), then poll
        rep.save(live_dir, epoch=1,
                 source_digest=shard_digest(shard_dir))
        swapped = ref.poll()
        if not swapped or ref.epoch != 1:
            failures.append(f"poll did not adopt epoch 1 "
                            f"(swapped={swapped}, epoch={ref.epoch})")
        vec1 = jnp.asarray(plan.vector(ref))
        if bool(jnp.array_equal(vec0, vec1)):
            failures.append("plan vector unchanged by the new epoch "
                            "(no tuned selection reached a plan site)")
        gen1 = serve_pass(cfg, steps, params, prompts, args.tokens, vec1)
        gen1.block_until_ready()
        sizes1 = cache_sizes(steps)
        recompiles = sum(b - a for a, b in zip(sizes0, sizes1))
        emit("fleet_retune/hotswap_recompilations", float(recompiles),
             f"cache sizes {sizes0} -> {sizes1}")
        if recompiles != 0:
            failures.append(f"hot swap triggered {recompiles} "
                            "recompilation(s); must be zero")
        if not bool(jnp.array_equal(gen0, gen1)):
            failures.append("tuned epoch changed the generated tokens")

        # -- 4. staleness: a delayed epoch-0 writer must be refused ----------
        from repro.core import profiles as profiles_mod
        profiles_mod.write_manifest(live_dir, 0)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            stale_swapped = ref.poll()
        if stale_swapped or ref.epoch != 1:
            failures.append("stale epoch 0 manifest was adopted")
        if not any("stale" in str(w.message) for w in wlog):
            failures.append("stale manifest refused without a warning")
        emit("fleet_retune/stale_epoch_refused",
             float(not stale_swapped and ref.epoch == 1))

        # -- 5. exploration budget -> feedback -> epoch 2 --------------------
        ex_rng = np.random.default_rng(1)
        vec2, explored = plan.explore(ref, eps=args.eps, rng=ex_rng)
        vec2 = jnp.asarray(vec2)
        serve_pass(cfg, steps, params, prompts, args.tokens,
                   vec2).block_until_ready()
        sizes2 = cache_sizes(steps)
        if sizes2 != sizes1:
            failures.append("exploration vector triggered recompilation")
        emit("fleet_retune/explored_sites", float(len(explored)),
             f"eps={args.eps}")
        if args.eps >= 1.0 and len(plan) and not explored:
            failures.append("eps=1 exploration flipped no site")
        # stand-in for wall clock: cost-model latency + measurement noise
        for (cell, _ph), impl in explored.items():
            base_t = backend.latency(cell, impl)
            for _ in range(4):
                rec2.observe(cell, impl,
                             base_t * float(ex_rng.normal(1.0, 0.02)))
        rec2.flush(shard_dir, epoch=2)
        observed = load_shard_latencies(shard_dir)
        if explored and not observed:
            failures.append("exploration measurements did not round-trip "
                            "through the shard files")
        emit("fleet_retune/feedback_pairs", float(len(observed)))

        fb = tuner.FeedbackBackend(backend, observed)
        rep2 = tuner.tune_trace(Trace.merge_shards(shard_dir), backend=fb,
                                min_win=args.min_win)
        rep2.save(live_dir, epoch=2,
                  source_digest=shard_digest(shard_dir))
        if not ref.poll() or ref.epoch != 2:
            failures.append(f"epoch 2 not adopted (epoch={ref.epoch})")
        vec3 = jnp.asarray(plan.vector(ref))
        serve_pass(cfg, steps, params, prompts, args.tokens,
                   vec3).block_until_ready()
        if cache_sizes(steps) != sizes2:
            failures.append("epoch 2 hot swap triggered recompilation")
        emit("fleet_retune/final_epoch", float(ref.epoch))

    (out / "summary.json").write_text(json.dumps({
        "arch": cfg.name, "tp": args.tp, "topo": args.topo,
        "fleet": fleet, "merged_cells": len(fleet_trace),
        "merged_dispatches": fleet_trace.total(),
        "union_cost_us": {"default": cost_default * 1e6,
                          "merged": cost_merged * 1e6},
        "plan_sites": len(plan), "explored_sites": len(explored),
        "feedback_pairs": len(observed), "final_epoch": ref.epoch,
        "hotswap_recompilations": recompiles,
        "failures": failures,
    }, indent=1))

    for f in failures:
        print(f"ERROR: {f}", file=sys.stderr)
    return 1 if failures else 0


def run():
    # benchmarks/run.py entry point: smoke-sized so the suite stays fast
    rc = main(["--smoke"])
    if rc:
        raise RuntimeError("bench_fleet_retune smoke failed")


if __name__ == "__main__":
    raise SystemExit(main())
