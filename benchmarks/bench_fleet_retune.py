"""Fleet-scale online retuning: shards → merged profile → live hot-swap.

The continuous-retuning loop at serving scale, simulated on one host:

1. FLEET RECORDING — four "servers" serve a smoke LM over emulated tensor
   parallelism (``vmap(axis_name="model")``, the CPU stand-in for a TP
   mesh), each with a different traffic mix (batch / prompt length), each
   recording into a bounded ``trace.ShardRecorder`` and flushing an
   epoch-stamped shard file.
2. MERGE + TUNE — ``Trace.merge_shards`` folds the shard directory into
   one fleet trace (count summation, weight preserved);
   ``tuner.tune_trace`` emits per-phase profiles from the union workload.
   Gate: on the union workload, the merged-trace profile's modeled cost
   is <= every single-shard profile's (a shard only sees its own slice,
   so its profile leaves the other servers' cells untuned).
3. HOT SWAP — a serve loop built ONCE with ``api.tuned(store_ref=...,
   plan=...)`` keeps stepping while the tuned generation is published to
   ``$PGTUNE_PROFILE_DIR`` (profiles first, ``MANIFEST.json`` last).
   ``StoreRef.poll`` adopts the new epoch, ``Plan.vector(ref)`` re-derives
   the runtime dispatch vector, and the next steps serve the tuned impls.
   Gate: ZERO new jit compilations across the swap (``_cache_size()``
   instrumented) while the plan vector provably changed.
4. STALENESS — a delayed writer regressing the manifest to an older epoch
   is refused (warning, live generation keeps serving).
5. EXPLORATION — an epsilon slice of steps runs ``Plan.explore`` vectors
   (runner-up impls), latencies are fed back via
   ``ShardRecorder.observe`` → ``#@lat`` shard lines →
   ``tuner.FeedbackBackend``, and the next epoch is tuned from the
   fleet's own measurements and hot-swapped in the same way.

Wall-clock on this CPU container measures emulation overhead; decision
quality is the cost-model latency (same convention as the other
benchmarks).  Exploration "measurements" are therefore cost-model samples
with noise — the plumbing (shards, reservoirs, feedback override) is what
this benchmark exercises end to end.

  PYTHONPATH=src python benchmarks/bench_fleet_retune.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import warnings

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core import api, costmodel as cm, tuner
from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
from repro.core.trace import (ShardRecorder, Trace, load_shard_latencies,
                              shard_digest)
from repro.models import lm
from repro.models.params import init_tree


def make_params(cfg, tp):
    specs = lm.model_specs(cfg, tp=tp)

    def init(key):
        return init_tree(specs, key, fold=lax.axis_index("model"))

    return jax.jit(jax.vmap(init, axis_name="model", axis_size=tp,
                            in_axes=None, out_axes=0))(jax.random.key(0))


def make_steps(cfg, tp, s_max, batch):
    """Prefill/decode jits with a TRAILING replicated plan-vector arg —
    the vector must be an argument (not a closure) so new epochs are new
    VALUES to an already-compiled step, never new constants."""

    def init_c(_):
        return lm.init_caches(cfg, batch, s_max)

    def pf(p, c, prompts, vec):
        with api.plan_input(vec):
            return lm.prefill(p, cfg, {"tokens": prompts}, c)

    def dc(p, t, c, i, vec):
        with api.plan_input(vec):
            return lm.decode_step(p, cfg, t, c, i)

    j_init = jax.jit(jax.vmap(init_c, axis_name="model", axis_size=tp,
                              in_axes=None, out_axes=0))
    j_pf = jax.jit(jax.vmap(pf, axis_name="model",
                            in_axes=(0, 0, None, None)))
    j_dc = jax.jit(jax.vmap(dc, axis_name="model",
                            in_axes=(0, None, 0, None, None)))
    return j_init, j_pf, j_dc


def serve_pass(cfg, steps, params, prompts, n_tokens, vec):
    """One prefill + greedy decode pass, phase-tagged like launch/serve."""
    j_init, j_pf, j_dc = steps
    caches = j_init(0)
    with api.phase("prefill"):
        logits, caches = j_pf(params, caches, prompts, vec)
    tok = (jnp.argmax(logits[0][:, -1], axis=-1).astype(jnp.int32)[:, None]
           % cfg.vocab_size)
    out = [tok]
    with api.phase("decode"):
        for step in range(n_tokens - 1):
            lg, caches = j_dc(params, tok, caches,
                              jnp.int32(prompts.shape[1] + step), vec)
            tok = (jnp.argmax(lg[0][:, -1], axis=-1).astype(jnp.int32)
                   [:, None] % cfg.vocab_size)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def cache_sizes(steps):
    return tuple(s._cache_size() for s in steps)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--tp", type=int, default=2,
                    help="emulated model-axis size")
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--topo", default="bgq-like", choices=sorted(cm.PRESETS))
    ap.add_argument("--min-win", type=float, default=0.10)
    ap.add_argument("--eps", type=float, default=0.5,
                    help="exploration budget (fraction of plan sites)")
    ap.add_argument("--out", default="results/fleet_retune")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tiny fleet / token budget)")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos-injected run: quarantine, rollback and "
                         "coordinator gates (see chaos_main)")
    args = ap.parse_args(argv)

    if args.chaos:
        if args.out == "results/fleet_retune":
            args.out = "results/fleet_chaos"
        return chaos_main(args)

    if args.smoke:
        # eps=1 flips every multi-impl site — the exploration gate must be
        # deterministic in CI, not a coin flip over a handful of sites
        args.tokens, args.eps = 4, 1.0

    topo = cm.PRESETS[args.topo]
    cfg = get_config(args.arch).smoke()
    # four servers, four traffic mixes: (batch, prompt_len)
    fleet = [(1, 8), (2, 16), (1, 32), (2, 8)]
    s_max = max(pl for _, pl in fleet) + args.tokens + 8
    backend = tuner.CostModelBackend(topo)

    header()
    out = pathlib.Path(args.out)
    shard_dir = out / "shards"
    live_dir = out / "live_profiles"
    import shutil
    for d in (shard_dir, live_dir):
        shutil.rmtree(d, ignore_errors=True)
    for d in (out, shard_dir, live_dir):
        d.mkdir(parents=True, exist_ok=True)
    failures: list[str] = []

    # -- 1. fleet recording: one bounded shard per server --------------------
    rng = np.random.default_rng(0)
    for i, (batch, plen) in enumerate(fleet):
        params = make_params(cfg, args.tp)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
        rec = ShardRecorder(f"srv{i}", seed=i)
        steps = make_steps(cfg, args.tp, s_max, batch)
        with api.tuned(record=rec):
            serve_pass(cfg, steps, params, prompts, args.tokens,
                       jnp.zeros(1, jnp.int32))
        path = rec.flush(shard_dir, epoch=1)
        emit(f"fleet_retune/shard{i}/dispatches",
             float(Trace.load(path).total()), path.name)

    # -- 2. merge + tune: fleet profile must cover every server's slice ------
    fleet_trace = Trace.merge_shards(shard_dir).trace
    shard_traces = [Trace.load(p)
                    for p in sorted(shard_dir.glob("shard-*.jsonl"))]
    assert fleet_trace.total() == sum(t.total() for t in shard_traces)
    emit("fleet_retune/merged/cells", float(len(fleet_trace)))
    emit("fleet_retune/merged/dispatches", float(fleet_trace.total()))

    rep = tuner.tune_trace(fleet_trace, backend=backend,
                           min_win=args.min_win)
    cost_merged = sum(tuner.estimate_trace_cost(
        fleet_trace, backend, phases=rep.phase_profiles).values())
    cost_default = sum(tuner.estimate_trace_cost(fleet_trace,
                                                 backend).values())
    emit("fleet_retune/union_cost_default_us", cost_default * 1e6)
    emit("fleet_retune/union_cost_merged_us", cost_merged * 1e6,
         f"{cost_default / cost_merged:.2f}x" if cost_merged else "")
    for i, t in enumerate(shard_traces):
        rep_i = tuner.tune_trace(t, backend=backend, min_win=args.min_win)
        cost_i = sum(tuner.estimate_trace_cost(
            fleet_trace, backend, phases=rep_i.phase_profiles).values())
        emit(f"fleet_retune/union_cost_shard{i}_us", cost_i * 1e6)
        if cost_merged > cost_i * (1 + 1e-9):
            failures.append(
                f"merged profile costs {cost_merged:.3e}s on the union "
                f"workload, worse than shard {i}'s profile ({cost_i:.3e}s)")

    # -- 3. live serve + hot swap (zero re-jits) -----------------------------
    os.environ[PROFILE_DIR_ENV] = str(live_dir)
    ref = resolve_stores(watch=True)
    if ref.epoch >= 0:
        failures.append(f"empty live dir resolved to epoch {ref.epoch}")
    plan = api.Plan(capacity=64)
    batch, plen = fleet[1]
    params = make_params(cfg, args.tp)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
    steps = make_steps(cfg, args.tp, s_max, batch)
    rec2 = ShardRecorder("live", seed=99)

    with api.tuned(store_ref=ref, plan=plan, record=rec2):
        vec0 = jnp.asarray(plan.vector(ref))
        gen0 = serve_pass(cfg, steps, params, prompts, args.tokens, vec0)
        gen0.block_until_ready()
        sizes0 = cache_sizes(steps)
        emit("fleet_retune/plan_sites", float(len(plan)),
             f"capacity {plan.capacity}")
        if len(plan) == 0:
            failures.append("no dispatch sites registered on the plan")

        # publish epoch 1 (profiles first, MANIFEST last), then poll
        rep.save(live_dir, epoch=1,
                 source_digest=shard_digest(shard_dir))
        swapped = ref.poll()
        if not swapped or ref.epoch != 1:
            failures.append(f"poll did not adopt epoch 1 "
                            f"(swapped={swapped}, epoch={ref.epoch})")
        vec1 = jnp.asarray(plan.vector(ref))
        if bool(jnp.array_equal(vec0, vec1)):
            failures.append("plan vector unchanged by the new epoch "
                            "(no tuned selection reached a plan site)")
        gen1 = serve_pass(cfg, steps, params, prompts, args.tokens, vec1)
        gen1.block_until_ready()
        sizes1 = cache_sizes(steps)
        recompiles = sum(b - a for a, b in zip(sizes0, sizes1))
        emit("fleet_retune/hotswap_recompilations", float(recompiles),
             f"cache sizes {sizes0} -> {sizes1}")
        if recompiles != 0:
            failures.append(f"hot swap triggered {recompiles} "
                            "recompilation(s); must be zero")
        if not bool(jnp.array_equal(gen0, gen1)):
            failures.append("tuned epoch changed the generated tokens")

        # -- 4. staleness: a delayed epoch-0 writer must be refused ----------
        from repro.core import profiles as profiles_mod
        profiles_mod.write_manifest(live_dir, 0)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            stale_swapped = ref.poll()
        if stale_swapped or ref.epoch != 1:
            failures.append("stale epoch 0 manifest was adopted")
        if not any("stale" in str(w.message) for w in wlog):
            failures.append("stale manifest refused without a warning")
        emit("fleet_retune/stale_epoch_refused",
             float(not stale_swapped and ref.epoch == 1))

        # -- 5. exploration budget -> feedback -> epoch 2 --------------------
        ex_rng = np.random.default_rng(1)
        vec2, explored = plan.explore(ref, eps=args.eps, rng=ex_rng)
        vec2 = jnp.asarray(vec2)
        serve_pass(cfg, steps, params, prompts, args.tokens,
                   vec2).block_until_ready()
        sizes2 = cache_sizes(steps)
        if sizes2 != sizes1:
            failures.append("exploration vector triggered recompilation")
        emit("fleet_retune/explored_sites", float(len(explored)),
             f"eps={args.eps}")
        if args.eps >= 1.0 and len(plan) and not explored:
            failures.append("eps=1 exploration flipped no site")
        # stand-in for wall clock: cost-model latency + measurement noise
        for (cell, _ph), impl in explored.items():
            base_t = backend.latency(cell, impl)
            for _ in range(4):
                rec2.observe(cell, impl,
                             base_t * float(ex_rng.normal(1.0, 0.02)))
        rec2.flush(shard_dir, epoch=2)
        observed = load_shard_latencies(shard_dir)
        if explored and not observed:
            failures.append("exploration measurements did not round-trip "
                            "through the shard files")
        emit("fleet_retune/feedback_pairs", float(len(observed)))

        fb = tuner.FeedbackBackend(backend, observed)
        rep2 = tuner.tune_trace(Trace.merge_shards(shard_dir).trace,
                                backend=fb, min_win=args.min_win)
        rep2.save(live_dir, epoch=2,
                  source_digest=shard_digest(shard_dir))
        if not ref.poll() or ref.epoch != 2:
            failures.append(f"epoch 2 not adopted (epoch={ref.epoch})")
        vec3 = jnp.asarray(plan.vector(ref))
        serve_pass(cfg, steps, params, prompts, args.tokens,
                   vec3).block_until_ready()
        if cache_sizes(steps) != sizes2:
            failures.append("epoch 2 hot swap triggered recompilation")
        emit("fleet_retune/final_epoch", float(ref.epoch))

    (out / "summary.json").write_text(json.dumps({
        "arch": cfg.name, "tp": args.tp, "topo": args.topo,
        "fleet": fleet, "merged_cells": len(fleet_trace),
        "merged_dispatches": fleet_trace.total(),
        "union_cost_us": {"default": cost_default * 1e6,
                          "merged": cost_merged * 1e6},
        "plan_sites": len(plan), "explored_sites": len(explored),
        "feedback_pairs": len(observed), "final_epoch": ref.epoch,
        "hotswap_recompilations": recompiles,
        "failures": failures,
    }, indent=1))

    for f in failures:
        print(f"ERROR: {f}", file=sys.stderr)
    return 1 if failures else 0


def _selection(cell, phase, ref):
    """The impl the live stores would dispatch for ``cell`` — mirrors
    ``estimate_trace_cost``'s resolution so synthesized fleet
    observations land on the (cell, impl) pairs drift is priced on."""
    from repro.core.collectives import REGISTRY
    name = ref.lookup(cell, phase)
    if name is None or name not in REGISTRY[cell.op]:
        return "default"
    impl = REGISTRY[cell.op][name]
    if (name != "default" and impl.requires_pow2
            and (cell.p & (cell.p - 1)) != 0):
        return "default"
    return name


def _worst_stores(trace, backend):
    """Per-phase stores that pick the WORST admissible impl for each
    (op, p) in the trace — a well-formed but genuinely bad generation,
    the kind a tune over poisoned measurements publishes."""
    import math
    from repro.core.collectives import REGISTRY
    from repro.core.profiles import Profile, ProfileStore, Range
    phases = {}
    for ph in trace.phases():
        profs = {}
        for cell in trace.cells(phase=ph):
            key = (cell.op, cell.p)
            if key in profs:
                continue
            worst, worst_t = None, -1.0
            for name, impl in REGISTRY[cell.op].items():
                if name == "default":
                    continue
                if impl.requires_pow2 and (cell.p & (cell.p - 1)) != 0:
                    continue
                t = backend.latency(cell, name)
                if math.isfinite(t) and t > worst_t:
                    worst, worst_t = name, t
            if worst is not None:
                profs[key] = Profile(cell.op, cell.p,
                                     [Range(0, 1 << 62, worst)])
        if profs:
            phases[ph] = ProfileStore(list(profs.values()))
    return phases


def chaos_main(args) -> int:
    """The chaos-injected fleet run (CI ``fleet-chaos`` job).

    Same loop as ``main``, under ``ft.ChaosMonkey`` fire.  Gates:

    A. torn + corrupt shards are QUARANTINED with exact weight
       accounting — the merged trace's total equals the surviving
       shards' sum, and the dropped weight equals the quarantined
       headers' claims;
    B. a manifest/profile-skewed publish is refused; the repaired
       republish (same epoch number, different manifest text — the case
       the content stamp exists for) is adopted;
    C. a published-but-regressing epoch trips ``api.EpochTripwire``,
       rolls back with ZERO recompilations and unchanged tokens, and the
       poisoned epoch is refused on re-publish;
    D. the coordinator flags the killed server and recommends a drift
       retune whose ratio reflects the MAD-filtered fleet observations
       (latency spikes rejected, not averaged in).

    Everything is seeded; the fault schedule and every gate are
    deterministic.
    """
    from repro.core import profiles as profiles_mod
    from repro.core.api import DispatchRecord, EpochTripwire
    from repro.ft import ChaosMonkey, FleetCoordinator

    topo = cm.PRESETS[args.topo]
    cfg = get_config(args.arch).smoke()
    tokens = 4
    fleet = [(1, 8), (2, 16), (1, 32), (2, 8)]
    s_max = max(pl for _, pl in fleet) + tokens + 8
    backend = tuner.CostModelBackend(topo)
    monkey = ChaosMonkey(seed=20170701)

    header()
    out = pathlib.Path(args.out)
    shard_dir = out / "shards"
    live_dir = out / "live_profiles"
    import shutil
    for d in (shard_dir, live_dir):
        shutil.rmtree(d, ignore_errors=True)
    for d in (out, shard_dir, live_dir):
        d.mkdir(parents=True, exist_ok=True)
    failures: list[str] = []

    # -- A. fleet recording under fire: tear srv1, corrupt srv2 --------------
    rng = np.random.default_rng(0)
    paths, clean_totals, claims = [], [], []
    for i, (batch, plen) in enumerate(fleet):
        params = make_params(cfg, args.tp)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
        rec = ShardRecorder(f"srv{i}", seed=i)
        steps = make_steps(cfg, args.tp, s_max, batch)
        with api.tuned(record=rec):
            serve_pass(cfg, steps, params, prompts, tokens,
                       jnp.zeros(1, jnp.int32))
        claims.append(rec.total())
        paths.append(rec.flush(shard_dir, epoch=1))
        clean_totals.append(Trace.load(paths[i]).total())
    monkey.tear_shard(paths[1], keep_frac=0.5)
    monkey.corrupt_line(paths[2])

    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        report = Trace.merge_shards(shard_dir)
    bad_names = sorted(n.path.name for n in report.quarantined)
    want_bad = sorted(p.name for p in (paths[1], paths[2]))
    emit("fleet_chaos/shards_quarantined", float(len(report.quarantined)),
         ", ".join(bad_names))
    if bad_names != want_bad:
        failures.append(f"quarantined {bad_names}, expected {want_bad}")
    surviving = clean_totals[0] + clean_totals[3]
    emit("fleet_chaos/merged_dispatches", float(report.trace.total()),
         f"surviving shards sum to {surviving}")
    if report.trace.total() != surviving:
        failures.append(
            f"merged weight {report.trace.total()} != surviving shards' "
            f"{surviving} — quarantine accounting is inexact")
    want_dropped = claims[1] + claims[2]
    emit("fleet_chaos/dropped_weight", float(report.dropped_weight),
         f"claimed {want_dropped}")
    if report.dropped_weight != want_dropped:
        failures.append(
            f"dropped_weight {report.dropped_weight} != quarantined "
            f"headers' claims {want_dropped}")
    print(report.summary())

    # -- live serving over the surviving fleet trace -------------------------
    fleet_trace = report.trace
    rep = tuner.tune_trace(fleet_trace, backend=backend,
                           min_win=args.min_win)
    os.environ[PROFILE_DIR_ENV] = str(live_dir)
    ref = resolve_stores(watch=True)
    plan = api.Plan(capacity=64)
    batch, plen = fleet[0]
    params = make_params(cfg, args.tp)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
    steps = make_steps(cfg, args.tp, s_max, batch)

    with api.tuned(store_ref=ref, plan=plan):
        vec0 = jnp.asarray(plan.vector(ref))
        gen0 = serve_pass(cfg, steps, params, prompts, tokens, vec0)
        gen0.block_until_ready()
        sizes0 = cache_sizes(steps)

        rep.save(live_dir, epoch=1, source_digest=shard_digest(shard_dir))
        if not ref.poll() or ref.epoch != 1:
            failures.append(f"epoch 1 not adopted (epoch={ref.epoch})")

        # -- B. manifest/profile skew refused; repaired republish lands ------
        rep.save(live_dir, epoch=2, source_digest="sha256:chaos-e2")
        monkey.skew_profiles(live_dir)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            skew_swapped = ref.poll()
        skew_refused = (not skew_swapped and ref.epoch == 1
                        and any("skew" in str(w.message) for w in wlog))
        emit("fleet_chaos/manifest_skew_refused", float(skew_refused))
        if not skew_refused:
            failures.append("manifest/profile skew was not refused "
                            f"(swapped={skew_swapped}, epoch={ref.epoch})")
        rep.save(live_dir, epoch=2, source_digest="sha256:chaos-e2-fixed")
        if not ref.poll() or ref.epoch != 2:
            failures.append(f"repaired epoch 2 not adopted "
                            f"(epoch={ref.epoch})")
        vec2 = jnp.asarray(plan.vector(ref))
        gen2 = serve_pass(cfg, steps, params, prompts, tokens, vec2)
        gen2.block_until_ready()

        # -- C. regressing epoch 3 -> tripwire rollback, zero re-jits --------
        def live_cost():
            return sum(tuner.estimate_trace_cost(
                fleet_trace, backend, base=ref.base,
                phases=ref.phases).values())

        cost_good = live_cost()
        tw = EpochTripwire(ref, threshold=1.3, window=4, min_samples=2)
        for _ in range(3):
            tw.observe(cost_good)
        bad_phases = _worst_stores(fleet_trace, backend)
        for sub in [p for p in live_dir.iterdir() if p.is_dir()]:
            shutil.rmtree(sub)      # epoch 3 replaces the phase stores
        for ph, store in bad_phases.items():
            store.save(live_dir / ph)
        profiles_mod.write_manifest(live_dir, 3,
                                    source_digest="sha256:chaos-e3")
        if not ref.poll() or ref.epoch != 3:
            failures.append(f"bad epoch 3 not adopted (epoch={ref.epoch})")
        vec3 = jnp.asarray(plan.vector(ref))
        serve_pass(cfg, steps, params, prompts, tokens,
                   vec3).block_until_ready()
        cost_bad = live_cost()
        emit("fleet_chaos/bad_epoch_regression",
             cost_bad / cost_good if cost_good else 0.0,
             f"{cost_good * 1e6:.1f} -> {cost_bad * 1e6:.1f} us")
        if cost_bad <= 1.3 * cost_good:
            failures.append(
                f"injected epoch 3 does not regress past the tripwire "
                f"threshold ({cost_bad:.3e} vs {cost_good:.3e})")
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            fired = [tw.observe(cost_bad) for _ in range(3)]
        emit("fleet_chaos/rollback_fired", float(any(fired)),
             f"fired={tw.fired}")
        if tw.fired != [(3, 2)]:
            failures.append(f"tripwire fired {tw.fired}, expected "
                            "[(3, 2)] (bad epoch 3 -> restored 2)")
        vec_r = jnp.asarray(plan.vector(ref))
        if not bool(jnp.array_equal(vec_r, vec2)):
            failures.append("rolled-back plan vector differs from the "
                            "restored epoch's")
        gen_r = serve_pass(cfg, steps, params, prompts, tokens, vec_r)
        gen_r.block_until_ready()
        if not bool(jnp.array_equal(gen_r, gen2)):
            failures.append("rollback changed the generated tokens")
        # the poisoned epoch must be refused even on a fresh republish
        profiles_mod.write_manifest(live_dir, 3,
                                    source_digest="sha256:chaos-e3-retry")
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            re_swapped = ref.poll()
        poisoned_refused = (not re_swapped and ref.epoch == 2
                            and any("poisoned" in str(w.message)
                                    for w in wlog))
        emit("fleet_chaos/poisoned_epoch_refused", float(poisoned_refused))
        if not poisoned_refused:
            failures.append("poisoned epoch 3 re-publish was adopted "
                            "(or refused without a warning)")
        recompiles = sum(b - a
                         for a, b in zip(sizes0, cache_sizes(steps)))
        emit("fleet_chaos/recompilations", float(recompiles),
             "across skew + bad epoch + rollback")
        if recompiles != 0:
            failures.append(f"{recompiles} recompilation(s) across the "
                            "chaos swaps; must be zero")

    # -- D. coordinator: killed server + MAD-robust drift retune -------------
    now = [0.0]
    coord = FleetCoordinator(shard_dir, ref, backend=backend,
                             heartbeat_timeout=30.0,
                             drift_threshold=1.5,
                             clock=lambda: now[0])
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        st1 = coord.scan()     # everyone beat at epoch 1
    monkey.kill_server("srv3", at_epoch=2)
    now[0] += 60.0
    spiked = 0
    for i in range(len(fleet)):
        if not monkey.alive(f"srv{i}", 2):
            continue
        rec = ShardRecorder(f"srv{i}", seed=100 + i)
        for (cell, ph), _n in sorted(fleet_trace.histogram().items()):
            rec.append(DispatchRecord(cell, "default", ph))
            name = _selection(cell, ph, ref)
            for _ in range(3):           # hardware drifted 2x slower
                rec.observe(cell, name, 2.0 * backend.latency(cell, name))
        p = rec.flush(shard_dir, epoch=2)
        if i == 0:                        # one server caught a hiccup
            spiked = monkey.spike_latencies(p, factor=100.0)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        st2 = coord.scan()
    print(st1.summary())
    print(st2.summary())
    emit("fleet_chaos/dead_servers", float(len(st2.dead)),
         ", ".join(st2.dead) or "-")
    if st2.dead != ["srv3"]:
        failures.append(f"coordinator flagged dead={st2.dead}, "
                        "expected ['srv3']")
    emit("fleet_chaos/drift", float(st2.drift or 0.0),
         f"{spiked} spiked sample(s) MAD-rejected")
    if st2.drift is None or not (1.5 < st2.drift < 3.0):
        failures.append(
            f"drift {st2.drift} outside (1.5, 3.0) — 2x-slower fleet "
            "observations should dominate; spikes must be rejected")
    if not (st2.retune and any("dead" in r for r in st2.reasons)
            and any("drift" in r for r in st2.reasons)):
        failures.append(f"coordinator did not recommend a retune for "
                        f"both failure and drift: {st2.reasons}")
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        obs = load_shard_latencies(
            shard_dir, skip=[n.path for n in report.quarantined])
    fb = tuner.FeedbackBackend(backend, obs)
    emit("fleet_chaos/mad_rejected", float(fb.rejected),
         f"{spiked} injected")
    if spiked and fb.rejected < spiked:
        failures.append(f"MAD filter rejected {fb.rejected} < {spiked} "
                        "injected spike(s)")

    (out / "summary.json").write_text(json.dumps({
        "arch": cfg.name, "tp": args.tp, "topo": args.topo,
        "chaos_events": [dataclasses.asdict(e) for e in monkey.events],
        "quarantined": bad_names,
        "merged_dispatches": report.trace.total(),
        "dropped_weight": report.dropped_weight,
        "rollback_fired": tw.fired,
        "recompilations": recompiles,
        "dead_servers": st2.dead,
        "drift": st2.drift,
        "mad_rejected": fb.rejected,
        "failures": failures,
    }, indent=1))

    for f in failures:
        print(f"ERROR: {f}", file=sys.stderr)
    return 1 if failures else 0


def run():
    # benchmarks/run.py entry point: smoke-sized so the suite stays fast
    rc = main(["--smoke"])
    if rc:
        raise RuntimeError("bench_fleet_retune smoke failed")


if __name__ == "__main__":
    raise SystemExit(main())
