"""Profile-driven decode serving: record → tune_trace → re-serve.

The first end-to-end run of the paper's offline→online pipeline against
*real model traffic* rather than synthetic size sweeps:

1. Serve a smoke LM with tensor parallelism emulated over
   ``vmap(axis_name="model")`` (the CPU stand-in for a TP mesh — the same
   dispatcher path shard_map takes) and record a phase-tagged workload
   trace: prefill-phase collectives + decode-phase collectives.
2. ``tuner.tune_trace`` replays the recorded (op, p, nbytes, phase) mix
   against the cost-model backend and emits per-phase ``ProfileStore``s.
3. Re-serve with ``api.tuned(phase_profiles=...)``: the decode steps now
   dispatch to the tuned mock-ups (visible in the Listing-2 footer), and
   the modeled per-step collective latency drops.

Wall-clock numbers on this CPU container measure emulation overhead, not
fabric time — the decision-quality number is the cost-model latency, same
as launch/dryrun's tuned-vs-default panel.  Artifacts (trace JSONL, tuned
``.pgtune`` profiles, dispatch footers) are written to ``--out`` so CI can
catch profile-format drift.

  PYTHONPATH=src python benchmarks/bench_decode_profile.py --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core import api, costmodel as cm, tuner
from repro.core.trace import Trace
from repro.models import lm
from repro.models.params import init_tree


def serve_once(cfg, tp, params, prompts, s_max, n_tokens, *,
               phase_profiles=None, profiles=None):
    """One prefill + greedy-decode pass under a fresh tuned context.

    Fresh local closures per call → fresh jit caches, so dispatch re-runs
    (and re-records) for each serving variant.
    """
    batch = prompts.shape[0]

    def init_c(_):
        return lm.init_caches(cfg, batch, s_max)

    def pf(p, c):
        return lm.prefill(p, cfg, {"tokens": prompts}, c)

    def dc(p, t, c, i):
        return lm.decode_step(p, cfg, t, c, i)

    vmap = jax.vmap
    j_init = jax.jit(vmap(init_c, axis_name="model", axis_size=tp,
                          in_axes=None, out_axes=0))
    j_pf = jax.jit(vmap(pf, axis_name="model"))
    j_dc = jax.jit(vmap(dc, axis_name="model", in_axes=(0, None, 0, None)))

    with api.tuned(profiles=profiles, phase_profiles=phase_profiles) as ctx:
        caches = j_init(0)
        with api.phase("prefill"):
            t0 = time.perf_counter()
            logits, caches = j_pf(params, caches)
            logits.block_until_ready()
            t_prefill = time.perf_counter() - t0
        tok = (jnp.argmax(logits[0][:, -1], axis=-1).astype(jnp.int32)
               [:, None] % cfg.vocab_size)
        out = [tok]
        with api.phase("decode"):
            t0 = time.perf_counter()
            for step in range(n_tokens - 1):
                lg, caches = j_dc(params, tok, caches,
                                  jnp.int32(prompts.shape[1] + step))
                tok = (jnp.argmax(lg[0][:, -1], axis=-1).astype(jnp.int32)
                       [:, None] % cfg.vocab_size)
                out.append(tok)
            tok.block_until_ready()
            t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    return t_prefill, t_decode / max(n_tokens - 1, 1), gen, ctx


def modeled_step_latency(record, topo, phase):
    """Cost-model collective seconds of the recorded dispatches in one
    phase (the first traced step — jit caches mean each step dispatches
    once)."""
    total = 0.0
    for rec in record:
        if rec.phase != phase:
            continue
        try:
            total += cm.latency_cell(rec.cell, rec.impl, topo)
        except KeyError:
            pass
    return total


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--tp", type=int, default=4,
                    help="emulated model-axis size")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--topo", default="bgq-like",
                    choices=sorted(cm.PRESETS),
                    help="fabric preset for the tuning backend")
    ap.add_argument("--min-win", type=float, default=0.10)
    ap.add_argument("--out", default="results/decode_profile")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tiny batch/seq/token budget)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.batch, args.prompt_len, args.tokens = 2, 8, 4
        args.tp = min(args.tp, 2)

    topo = cm.PRESETS[args.topo]
    cfg = get_config(args.arch).smoke()
    s_max = args.prompt_len + args.tokens + 8
    specs = lm.model_specs(cfg, tp=args.tp)

    def init(key):
        return init_tree(specs, key, fold=lax.axis_index("model"))

    params = jax.jit(jax.vmap(init, axis_name="model", axis_size=args.tp,
                              in_axes=None, out_axes=0))(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    header()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    # -- 1. default serve: record the workload trace -------------------------
    pf_d, dc_d, gen_d, ctx_d = serve_once(cfg, args.tp, params, prompts,
                                          s_max, args.tokens)
    trace = Trace.from_context(ctx_d)
    trace.save(out / "decode_trace.jsonl")
    (out / "footer_default.txt").write_text(api.format_footer(ctx_d) + "\n")
    emit("decode_profile/default/prefill_ms", pf_d * 1e3)
    emit("decode_profile/default/step_us", dc_d * 1e6, "wall-clock emulation")

    # -- 2. trace-replay tuning ----------------------------------------------
    rep = tuner.tune_trace(trace, backend=tuner.CostModelBackend(topo),
                           min_win=args.min_win)
    rep.save(out / "profiles")
    for line in rep.summary().splitlines():
        print(f"# {line}")

    # -- 3. tuned serve -------------------------------------------------------
    pf_t, dc_t, gen_t, ctx_t = serve_once(cfg, args.tp, params, prompts,
                                          s_max, args.tokens,
                                          phase_profiles=rep.phase_profiles)
    footer = api.format_footer(ctx_t)
    (out / "footer_tuned.txt").write_text(footer + "\n")
    emit("decode_profile/tuned/prefill_ms", pf_t * 1e3)
    emit("decode_profile/tuned/step_us", dc_t * 1e6, "wall-clock emulation")

    same = bool(jnp.array_equal(gen_d, gen_t))
    emit("decode_profile/tokens_identical", 0.0, str(same))

    m_def = modeled_step_latency(ctx_d.record, topo, "decode")
    m_tun = modeled_step_latency(ctx_t.record, topo, "decode")
    emit("decode_profile/modeled_decode_collectives_default_us", m_def * 1e6)
    emit("decode_profile/modeled_decode_collectives_tuned_us", m_tun * 1e6,
         f"{m_def / m_tun:.2f}x" if m_tun > 0 else "")

    # v1-sunset criterion, machine-checked (ROADMAP "Trace v1 sunset"):
    # artifacts freshly written by THIS pipeline must re-load without any
    # deprecation path firing — scoped to our own artifacts so unrelated
    # library DeprecationWarnings can't fail the job
    import warnings

    from repro.core.profiles import ProfileStore
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        try:
            Trace.load(out / "decode_trace.jsonl")
            for sub in sorted((out / "profiles").iterdir()):
                if sub.is_dir():
                    ProfileStore.load(sub)
        except DeprecationWarning as w:
            print(f"ERROR: freshly written artifact re-loads through a "
                  f"deprecated parse path: {w}", file=sys.stderr)
            return 1
    emit("decode_profile/artifacts_current_schema", 1.0)

    tuned_decode = [r for r in ctx_t.record if r.phase == "decode"]
    nondefault = sorted({r.impl for r in tuned_decode if r.impl != "default"})
    emit("decode_profile/tuned_nondefault_impls", float(len(nondefault)),
         ";".join(nondefault))
    print(footer)

    (out / "summary.json").write_text(json.dumps({
        "arch": cfg.name, "tp": args.tp, "topo": args.topo,
        "trace_cells": len(trace), "trace_dispatches": trace.total(),
        "phases": trace.phases(),
        "modeled_decode_us": {"default": m_def * 1e6, "tuned": m_tun * 1e6},
        "wall_step_us": {"default": dc_d * 1e6, "tuned": dc_t * 1e6},
        "tuned_nondefault_impls": nondefault,
        "tokens_identical": same,
    }, indent=1))

    if not nondefault:
        print("ERROR: tuned decode run selected no non-default mock-ups "
              "(profile pipeline regressed)", file=sys.stderr)
        return 1
    if not same:
        print("ERROR: tuned serving changed the generated tokens",
              file=sys.stderr)
        return 1
    return 0


def run():
    # benchmarks/run.py entry point: smoke-sized so the suite stays fast
    rc = main(["--smoke"])
    if rc:
        raise RuntimeError("bench_decode_profile smoke failed")


if __name__ == "__main__":
    raise SystemExit(main())
