# One function per paper table/figure. Prints ``name,us_per_call,derived``.
from __future__ import annotations

import traceback

from benchmarks.common import header


def main() -> None:
    header()
    from benchmarks import (bench_case_allreduce, bench_case_reduce,
                            bench_collective_matmul, bench_decode_profile,
                            bench_dispatch, bench_guidelines, bench_hierarchy,
                            bench_measured, bench_nrep_lookup, bench_roofline)
    for mod in (bench_guidelines,       # Figs. 3/4/5 violation tables
                bench_case_reduce,      # Fig. 6 Reduce<=Allreduce case
                bench_case_allreduce,   # Fig. 7 rs+agv beats everything
                bench_collective_matmul,  # fused-vs-unfused overlap model
                bench_dispatch,         # api._select fast-path overhead
                bench_nrep_lookup,      # Alg.1/Eq.1 + O(log M) lookup
                bench_measured,         # ReproMPI-style measured pipeline
                bench_roofline,         # §Roofline per dry-run cell
                bench_hierarchy,        # per-axis tiers + hier must-wins
                bench_decode_profile):  # trace-replay serving (smoke)
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},0.0,ERROR")


if __name__ == "__main__":
    main()
