"""Measured-latency pipeline on host devices (the offline tuning pass the
paper runs with ReproMPI): default vs mock-ups, barrier-synced wall clock.

On this container the bench process sees ONE device (axis size 1), so the
numbers are dispatch floors — the point is exercising the exact pipeline
that runs on a real pod (see tests/test_spmd_subprocess.py for 8-device
execution of every mock-up).
"""
from __future__ import annotations

import statistics

from benchmarks.common import emit
from repro.core import measure


def run():
    p = measure.axis_size()
    for op, impls in [
        ("allreduce", ["default", "allreduce_as_rsb_allgather"]),
        ("allgather", ["default", "allgather_as_allreduce"]),
        ("reducescatter", ["default", "rsb_as_allreduce"]),
    ]:
        for impl in impls:
            lat = measure.sample_latency(measure.host_cell(op, 4096), impl,
                                         20)
            med = statistics.median(lat) * 1e6
            emit(f"measured/p{p}/{op}/{impl}", med,
                 f"min={min(lat)*1e6:.1f}us")


if __name__ == "__main__":
    run()
