"""Paper §4.4.1 / Fig. 6: MPI_Reduce ≤ MPI_Allreduce case study.

The paper found Open MPI's Reduce slower than its own Allreduce for
128 kB-725 kB at 512 procs, repaired it with the mock-up, and showed a
fully parameter-tuned algorithm (in-order binary tree) still edges out the
mock-up.  Cost-model analogue: naive-default Reduce vs the GL14 mock-up vs
the best dedicated tree schedule.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import costmodel as cm

P = 512
NAIVE = cm.Topo("jupiter-naive", alpha=1.3e-6, link_bw=5e9, gamma=4e-12,
                default_pricing="naive")


def run():
    for nbytes in (32_768, 131_072, 262_144, 524_288, 1_048_576):
        t_def = cm.latency("reduce", "default", P, nbytes, NAIVE)
        t_mock = cm.latency("reduce", "reduce_as_allreduce", P, nbytes, NAIVE)
        t_tree = cm.latency("reduce", "reduce_as_tree", P, nbytes, NAIVE)
        emit(f"fig6/reduce_default/{nbytes}B", t_def * 1e6, "")
        emit(f"fig6/reduce_as_allreduce/{nbytes}B", t_mock * 1e6,
             f"vs_default=x{t_def / t_mock:.2f}")
        emit(f"fig6/reduce_param_tuned_tree/{nbytes}B", t_tree * 1e6,
             f"vs_mockup=x{t_mock / t_tree:.2f}")
        # the paper's finding: mock-up repairs the violation; dedicated
        # parameter tuning can still improve moderately
        assert t_mock < t_def


if __name__ == "__main__":
    run()
