"""§Roofline table: read the dry-run artifacts and print per-cell terms.

One row per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    files = sorted(DRYRUN.glob("*.json")) if DRYRUN.exists() else []
    if not files:
        emit("roofline/missing", 0.0, "run: python -m repro.launch.dryrun")
        return
    for f in files:
        d = json.loads(f.read_text())
        key = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] != "ok":
            emit(key, 0.0, d["status"])
            continue
        r = d["roofline"]
        step_ms = max(float(r["t_compute"][:-2]), float(r["t_memory"][:-2]),
                      float(r["t_collective"][:-2]))
        emit(key, step_ms * 1e3,
             f"bottleneck={r['bottleneck']}"
             f" useful={r['useful_flops_ratio']}"
             f" frac={r['roofline_fraction']}"
             f" coll={d['collectives'].get('total_bytes', 0)}")


if __name__ == "__main__":
    run()
