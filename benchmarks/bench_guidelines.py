"""Paper Figs. 3/4/5: guideline-violation tables per platform.

For each platform preset (Jupiter-like optimal fabric at p=512, the
JUQUEEN-like naive+HW-bcast fabric at p=1024 — the paper's 32x16 and 64x16
runs — and the v5e model axis at p=16), benchmark every mock-up against the
default via the cost model and report relative latency + violations, the
paper's Tuned-vs-Default panels.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import costmodel as cm
from repro.core import tuner

PLATFORMS = [
    ("jupiter_like_p512", cm.V5E_ICI, 512),
    ("juqueen_like_p1024", cm.BGQ_LIKE, 1024),
    ("v5e_model_axis_p16", cm.V5E_ICI, 16),
]

SIZES = (1, 8, 32, 100, 1024, 8192, 32768, 100_000, 1_048_576)


def run():
    for pname, topo, p in PLATFORMS:
        rep = tuner.tune(sizes=SIZES, axis_size=p,
                         backend=tuner.CostModelBackend(topo))
        n_pat = sum(1 for v in rep.violations if v.gl_kind == "pattern")
        emit(f"guidelines/{pname}/violations", 0.0,
             f"pattern={n_pat} profiles={len(rep.profiles)}")
        # per-op best-case speedup (the Figs. 3-5 headline numbers)
        best: dict[str, float] = {}
        for v in rep.violations:
            if v.gl_kind == "pattern":
                best[v.op] = max(best.get(v.op, 1.0), v.speedup)
        for op, sp in sorted(best.items()):
            # default latency at 32 KiB for scale (the paper's marked sizes)
            t_def = cm.latency(op, "default", p, 32768, topo) * 1e6
            emit(f"guidelines/{pname}/{op}", t_def,
                 f"best_mockup_speedup=x{sp:.2f}")


if __name__ == "__main__":
    run()
