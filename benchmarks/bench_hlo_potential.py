"""XLA-layer tuning potential over the model zoo (report-only mode).

For each zoo model, compile the real shard_map'd step on a forced host
mesh, interpose on the compiled HLO (``analysis/interpose``), and emit the
modeled collective totals: default lowering vs. best mock-up per site.
The headline per model is the "X.Yx on the table" ratio — what a tuned
library could recover without touching the model's code.

Rows (CSV, via benchmarks.common): per model, the modeled default total,
best-mock-up total, and the count of fused-matmul candidate sites the
adjacent-dot detector found.  Artifacts (tables + JSON) are written to
``--out`` so CI can diff them and gate on unmapped ops.

  PYTHONPATH=src python benchmarks/bench_hlo_potential.py
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

ARCHS = ["gemma3-1b", "llama3.2-3b"]
KINDS = ["train", "decode"]
MESH = (2, 4)

# before any jax import: the bench always runs on forced host devices
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={MESH[0] * MESH[1]}")

from benchmarks.common import emit, header  # noqa: E402
from repro.analysis.interpose import (HloParseError,  # noqa: E402
                                      compile_zoo_hlo, scan_potential)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(_ROOT / "results" /
                                         "hlo_potential"))
    ap.add_argument("--arch", action="append", default=[])
    args = ap.parse_args(argv)
    archs = args.arch or ARCHS
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    header()
    failed = False
    for arch in archs:
        for kind in KINDS:
            label = f"{arch}/{kind}"
            try:
                hlo, _info = compile_zoo_hlo(arch, kind=kind,
                                             mesh_shape=MESH)
                rep = scan_potential(hlo, label=label)
            except HloParseError as e:
                print(f"PARSE ERROR [{label}]: {e}", file=sys.stderr)
                failed = True
                continue
            n_fused = sum(1 for r in rep.rows if r.sc.fused)
            n_cand = sum(1 for r in rep.rows
                         if r.sc.adjacent_dot and not r.sc.fused)
            emit(f"hlo_potential/{arch}/{kind}/default",
                 rep.total_default() * 1e6,
                 f"sites={len(rep.rows)}")
            emit(f"hlo_potential/{arch}/{kind}/best",
                 rep.total_best() * 1e6,
                 f"potential={rep.potential():.2f}x fused={n_fused} "
                 f"fused_candidates={n_cand}")
            stem = f"{arch.replace('.', '_')}_{kind}"
            (out_dir / f"{stem}.json").write_text(
                json.dumps(rep.to_json(), indent=1) + "\n")
            (out_dir / f"{stem}.txt").write_text(rep.table() + "\n")
            if not rep.ok:
                print(f"UNMAPPED [{label}]: "
                      f"{[s.hlo_op for s in rep.unmapped]}",
                      file=sys.stderr)
                failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
